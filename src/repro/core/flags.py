"""Per-registration continuation flags (the API-redesign layer).

The paper (§3.5) attaches every control knob to the continuation request
at ``MPIX_Continue_init`` time; the follow-up proposal (and the
fibers-vs-pthreads companion paper) argue for *finer* control — flags that
travel with each individual ``MPIX_Continue[all]`` call, so one CR can
aggregate continuations with different completion semantics instead of the
application allocating a CR per knob combination.

``ContinueFlags`` is that per-registration override. Every field defaults
to ``None`` = "inherit the CR's ``ContinueInfo`` default"; a non-``None``
value overrides the CR for this registration only. Resolution happens once,
at registration, into a ``ResolvedPolicy`` carried by the ``Continuation``
itself — routing (poll_only queue vs scheduler), the immediate-completion
fast path, inline-execution eligibility, thread policy, and error policy
are all decided per registration from then on.

Fields (MPIX_CONT_* analogues noted):

* ``enqueue_complete``  — ``False``: an all-complete group reports
  ``flag=True`` without invoking the callback; ``True``: it is enqueued
  through the continuation machinery regardless.
* ``immediate``         — ``True``: the callback is safe to run inline even
  while the registering thread is still inside ``continue_when/all`` (opts
  out of the paper-§3.1 registration guard; MPIX_CONT_IMMEDIATE).
* ``defer_complete``    — ``True``: the callback never runs inline on the
  thread that *discovered* the completion; it is always deferred to a
  drain from an engine entry point (MPIX_CONT_DEFER_COMPLETE). Use when
  the callback takes locks the completing thread may hold.
* ``poll_only``         — route the ready continuation to the CR's private
  queue (runs only inside ``cr.test()``/``wait()``) instead of the
  engine scheduler.
* ``thread``            — "application" / "any": which threads may execute
  the callback (see ``ContinueInfo.thread``).
* ``volatile_statuses`` — ``True``: the caller's ``statuses`` list is
  volatile (may be reused immediately after the call); the engine snapshots
  into an internally-owned list and passes *that* to the callback
  (MPIX_CONT_REQBUF_VOLATILE analogue for the status buffer).
* ``on_error``          — per-registration error policy: ``"raise"`` (re-
  raised from the CR's next test/wait), ``"collect"`` (stored on
  ``cr.errors`` only), or a callable ``fn(exc)`` invoked with the
  exception (never stored).
* ``priority``          — scheduler hint: a registration with
  ``priority > 0`` is pushed to the *front* of the ready queue(s), so its
  callback drains ahead of already-queued normal-priority work (the serve
  front-end maps per-request QoS priority onto this; there is no CR-level
  counterpart — the default is 0).

``make_flags`` accepts a ``ContinueFlags``, a mapping (new-style field
names or the deprecated MPI-style ``mpi_continue_*`` string keys), and/or
kwargs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Union

from repro.core.info import (THREAD_ANY, THREAD_APPLICATION, ContinueInfo,
                             _coerce)

OnError = Union[str, Callable[[BaseException], None]]


@dataclasses.dataclass(frozen=True)
class ContinueFlags:
    """Per-registration overrides; ``None`` inherits the CR info default."""

    enqueue_complete: Optional[bool] = None
    immediate: Optional[bool] = None
    defer_complete: Optional[bool] = None
    poll_only: Optional[bool] = None
    thread: Optional[str] = None
    volatile_statuses: Optional[bool] = None
    on_error: Optional[OnError] = None
    priority: Optional[int] = None

    def __post_init__(self) -> None:
        if self.priority is not None and not isinstance(self.priority, int):
            raise ValueError(f"priority must be an int, "
                             f"got {self.priority!r}")
        if self.thread not in (None, THREAD_APPLICATION, THREAD_ANY):
            raise ValueError(f"thread must be 'application' or 'any', "
                             f"got {self.thread!r}")
        if self.on_error is not None and not callable(self.on_error) \
                and self.on_error not in ("raise", "collect"):
            raise ValueError(
                "on_error must be 'raise', 'collect', or a callable")
        if self.immediate and self.defer_complete:
            raise ValueError(
                "immediate=True (run inline even during registration) and "
                "defer_complete=True (never run inline at discovery) are "
                "contradictory")


@dataclasses.dataclass(frozen=True)
class ResolvedPolicy:
    """Flags resolved against a CR's ``ContinueInfo`` — no ``None`` left.

    Computed once at registration; the ``Continuation`` carries it so every
    later decision (routing, eligibility, error surfacing) is local to the
    registration, not the CR.
    """

    enqueue_complete: bool
    immediate: bool
    defer_complete: bool
    poll_only: bool
    thread: str
    volatile_statuses: bool
    on_error: OnError
    priority: int = 0


#: deprecated MPI-style string keys (mirrors ``core.info._KEYMAP``); kept
#: working so old call sites migrate at their own pace.
_FLAG_KEYMAP = {
    "mpi_continue_enqueue_complete": "enqueue_complete",
    "mpi_continue_immediate": "immediate",
    "mpi_continue_defer_complete": "defer_complete",
    "mpi_continue_poll_only": "poll_only",
    "mpi_continue_thread": "thread",
    "mpi_continue_volatile_statuses": "volatile_statuses",
    "mpi_continue_priority": "priority",
    "on_error": "on_error",
}

_BOOL_FIELDS = ("enqueue_complete", "immediate", "defer_complete",
                "poll_only", "volatile_statuses")


def make_flags(flags: Union[None, ContinueFlags, Mapping[str, Any]] = None,
               /, **kwargs: Any) -> Optional[ContinueFlags]:
    """Normalize a flags argument (instance, mapping, kwargs) or ``None``."""
    if flags is None and not kwargs:
        return None
    if isinstance(flags, ContinueFlags):
        if kwargs:
            return dataclasses.replace(flags, **kwargs)
        return flags
    fields: dict[str, Any] = {}
    for key, value in (flags or {}).items():
        field = _FLAG_KEYMAP.get(key, key)
        if field not in ContinueFlags.__dataclass_fields__:
            raise KeyError(f"unknown continuation flag: {key!r}")
        fields[field] = value
    fields.update(kwargs)
    for key in list(fields):
        if key in _BOOL_FIELDS and fields[key] is not None:
            fields[key] = _coerce("poll_only", fields[key])  # bool coercion
    return ContinueFlags(**fields)


def merge_flags(base: Optional[ContinueFlags],
                override: Optional[ContinueFlags]) -> Optional[ContinueFlags]:
    """Layer two flag sets: any non-``None`` field of ``override`` wins."""
    if override is None:
        return base
    if base is None:
        return override
    picked = {
        name: (getattr(override, name) if getattr(override, name) is not None
               else getattr(base, name))
        for name in ContinueFlags.__dataclass_fields__}
    return ContinueFlags(**picked)


def resolve(info: ContinueInfo,
            flags: Optional[ContinueFlags]) -> ResolvedPolicy:
    """CR info defaults, overridden by any non-``None`` per-registration
    flag. ``immediate``/``defer_complete``/``volatile_statuses`` (default
    ``False``) and ``priority`` (default 0) have no CR-level
    counterpart."""
    if flags is None:
        return ResolvedPolicy(
            enqueue_complete=info.enqueue_complete, immediate=False,
            defer_complete=False, poll_only=info.poll_only,
            thread=info.thread, volatile_statuses=False,
            on_error=info.on_error, priority=0)

    def pick(override, default):
        return default if override is None else override

    return ResolvedPolicy(
        enqueue_complete=pick(flags.enqueue_complete, info.enqueue_complete),
        immediate=pick(flags.immediate, False),
        defer_complete=pick(flags.defer_complete, False),
        poll_only=pick(flags.poll_only, info.poll_only),
        thread=pick(flags.thread, info.thread),
        volatile_statuses=pick(flags.volatile_statuses, False),
        on_error=pick(flags.on_error, info.on_error),
        priority=pick(flags.priority, 0))
