"""Reference *application-space* completion manager (the baseline).

This is the pattern the paper's evaluation sections describe and beat:

* **PaRSEC** (paper §5.3/Fig. 5): the communication thread keeps a
  deliberately small *active* request window passed to ``MPI_Testsome`` plus
  a *pending* list promoted into the window as slots free up — cheap testing,
  but recently-posted-yet-complete operations are not noticed until promoted.
* **ExaHyPE** (paper §5.4): an *offloading manager* maps request groups to
  callbacks "using multiple parallel data structures", progressed by passing
  a subset of active requests to ``MPI_Testsome``.

``TestsomeManager`` reproduces both artifacts faithfully so benchmarks can
measure the latency/throughput gap against the continuation engine, and so
the LoC/complexity comparison (paper Table 3) is grounded in real code in
this repo.
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.completable import Completable
from repro.core.status import Status

Callback = Callable[[Optional[List[Status]], Any], None]


class TestsomeManager:
    """Poll-based request manager with a bounded active window.

    The three parallel data structures below mirror the reference ExaHyPE
    offloading manager (request array / request→group map / group→callback
    map) that the paper replaces with a single ``MPIX_Continueall`` call.
    """

    __test__ = False     # name starts with "Test" but this is not a test class

    def __init__(self, window: int = 32) -> None:
        self.window = window
        self._lock = threading.Lock()
        self._group_seq = itertools.count()
        # -- parallel data structures (the complexity the paper removes) --
        self._active: List[Completable] = []          # testsome window
        self._pending: List[Completable] = []         # awaiting promotion
        self._op_group: Dict[int, int] = {}           # id(op) -> group id
        self._groups: Dict[int, dict] = {}            # group id -> record
        self.stats = {"submitted": 0, "test_calls": 0, "ops_tested": 0,
                      "callbacks": 0}

    # -------------------------------------------------------------- submit
    def submit(self, ops: Sequence[Completable], cb: Callback,
               cb_data: Any = None, want_statuses: bool = False,
               need: Optional[int] = None,
               indices_out: Optional[List[int]] = None) -> int:
        """Register a request group whose combined completion triggers ``cb``.

        ``need`` selects first-k-of-n semantics (the engine's
        ``continue_some`` analogue, kept feature-comparable here): the
        callback fires when ``need`` ops of the group completed; the
        group's losers are dropped from the window/pending lists (late
        completions are ignored). Default: all of them.

        ``indices_out``: caller list rewritten with the completed op
        indices (completion order, ``MPI_Waitsome`` style) before the
        callback fires — how first-k callers learn which ops won.
        """
        gid = next(self._group_seq)
        k = len(ops) if need is None else int(need)
        if not 1 <= k <= len(ops):
            raise ValueError(f"need 1 <= need <= {len(ops)}, got {k}")
        record = {
            "cb": cb, "cb_data": cb_data,
            "remaining": k,
            "ops": list(ops),
            "indices": [],          # completion order, Waitsome-style
            "indices_out": indices_out,
            "statuses": [Status() for _ in ops] if want_statuses else None,
            "index": {id(op): i for i, op in enumerate(ops)},
        }
        with self._lock:
            self._groups[gid] = record
            for op in ops:
                self._op_group[id(op)] = gid
                if len(self._active) < self.window:
                    self._active.append(op)
                else:
                    self._pending.append(op)
            self.stats["submitted"] += len(ops)
        return gid

    def submit_any(self, ops: Sequence[Completable], cb: Callback,
                   cb_data: Any = None, want_statuses: bool = False,
                   indices_out: Optional[List[int]] = None) -> int:
        """First-of-n (``MPI_Testany`` analogue in application space)."""
        return self.submit(ops, cb, cb_data, want_statuses, need=1,
                           indices_out=indices_out)

    def submit_some(self, ops: Sequence[Completable], k: int, cb: Callback,
                    cb_data: Any = None, want_statuses: bool = False,
                    indices_out: Optional[List[int]] = None) -> int:
        """First-k-of-n (``MPI_Testsome`` analogue in application space)."""
        return self.submit(ops, cb, cb_data, want_statuses, need=k,
                           indices_out=indices_out)

    # ------------------------------------------------------------- progress
    def testsome(self) -> int:
        """One progress pass: linear walk of the active window (the
        ``MPI_Testsome`` analogue), compact, promote pending, fire callbacks
        for fully-complete groups. Returns number of callbacks invoked.
        """
        fired: List[Tuple[Callback, Optional[List[Status]], Any]] = []
        with self._lock:
            self.stats["test_calls"] += 1
            self.stats["ops_tested"] += len(self._active)
            still_active: List[Completable] = []
            dropped: set = set()       # loser ops of first-k groups
            for op in self._active:
                if op.done():
                    gid = self._op_group.pop(id(op), None)
                    if gid is None:
                        continue
                    rec = self._groups[gid]
                    if rec["statuses"] is not None:
                        rec["statuses"][rec["index"][id(op)]] = op.status
                    rec["indices"].append(rec["index"][id(op)])
                    rec["remaining"] -= 1
                    if rec["remaining"] == 0:
                        if rec["indices_out"] is not None:
                            rec["indices_out"][:] = rec["indices"]
                        del self._groups[gid]
                        # first-k groups: drop the losers everywhere so
                        # their late completions are ignored
                        for other in rec["ops"]:
                            if self._op_group.pop(id(other), None) is not None:
                                dropped.add(id(other))
                        fired.append((rec["cb"], rec["statuses"], rec["cb_data"]))
                else:
                    still_active.append(op)
            self._active = [op for op in still_active
                            if id(op) not in dropped]
            if dropped:
                self._pending = [op for op in self._pending
                                 if id(op) not in dropped]
            # promote pending requests into freed window slots
            free = self.window - len(self._active)
            if free > 0 and self._pending:
                self._active.extend(self._pending[:free])
                del self._pending[:free]
        for cb, statuses, cb_data in fired:
            cb(statuses, cb_data)
        self.stats["callbacks"] += len(fired)
        return len(fired)

    def drain(self, *, max_iters: int = 10_000_000) -> None:
        """Progress until every submitted group has fired."""
        for _ in range(max_iters):
            with self._lock:
                if not self._groups:
                    return
            self.testsome()
        raise RuntimeError("TestsomeManager.drain did not converge")

    @property
    def outstanding(self) -> int:
        with self._lock:
            return len(self._groups)
