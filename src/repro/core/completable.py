"""Completable operations — what continuations can be attached to.

In MPI the unit of asynchrony is the request; in a JAX framework it is
anything that completes out-of-line with the control thread:

* ``ArrayOp``     — a (pytree of) ``jax.Array``; complete when dispatch has
                    finished materializing every leaf (``Array.is_ready()``).
* ``HostTaskOp``  — a ``concurrent.futures.Future`` (checkpoint shard writes,
                    data-pipeline fills, metric fetches). Push-notified.
* ``TimerOp``     — completes at a deadline (heartbeat/straggler timeouts).
* ``PredicateOp`` — completes when a user predicate flips true.
* ``MessageOp``   — transport send/recv handles (see ``transport.py``).
* ``ContinuationRequest`` — CRs are completable themselves (paper §3.2:
  a continuation may be attached to a CR and registered with another CR).
* ``CombinedOp``  — a composite over child ops built by the combinators
  ``when_all`` / ``when_any`` / ``when_some``: completes when k of n
  children have completed, detaching (optionally cancelling) the losers.

Ops follow the paper's ownership rule: attaching a continuation *consumes*
the handle (at most one continuation per op; re-attach only for persistent
ops after restart). Combinators consume their children the same way; the
losers of a ``when_any``/``when_some`` get their handles released back to
the caller when the combinator fires.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Optional, Sequence

from repro.core.status import OneShotLatch, OpState, Status

ReadyHook = Callable[["Completable", Status], None]


class Completable:
    """Base class for asynchronous operations.

    Subclasses either support *polling* (override ``_poll``) or *push*
    notification (call ``_complete`` from wherever the work finishes), or
    both. The continuation engine uses push hooks when available and falls
    back to polling scans during progress calls — mirroring an MPI library
    discovering completions inside any MPI call.
    """

    #: persistent ops may be restarted and re-attached (MPI persistent reqs)
    persistent: bool = False

    def __init__(self) -> None:
        self._latch = OneShotLatch()
        self._state = OpState.PENDING
        self._status = Status()
        self._hooks: list[ReadyHook] = []
        self._hook_lock = threading.Lock()
        self._attached = False

    # -- completion publishing ------------------------------------------------
    def _complete(self, status: Optional[Status] = None,
                  state: OpState = OpState.COMPLETE) -> bool:
        """Publish completion exactly once; fire hooks on the caller thread."""
        if not self._latch.fire():
            return False
        if status is not None:
            self._status = status
        self._state = state
        if state == OpState.CANCELLED:
            self._status.cancelled = True
        with self._hook_lock:
            hooks, self._hooks = list(self._hooks), []
        for hook in hooks:
            hook(self, self._status)
        return True

    # -- probing ----------------------------------------------------------------
    def _poll(self) -> bool:
        """Subclass probe: return True when the underlying work is done.

        Only called while PENDING; must be cheap and non-blocking.
        """
        return False

    def done(self) -> bool:
        """Non-blocking completion test (drives poll-mode ops forward)."""
        if self._state is not OpState.PENDING:
            return True
        if self._poll():
            self._complete(self._make_status())
            return True
        return False

    def _make_status(self) -> Status:
        return self._status

    # -- introspection ---------------------------------------------------------
    @property
    def state(self) -> OpState:
        return self._state

    @property
    def status(self) -> Status:
        return self._status

    @property
    def supports_push(self) -> bool:
        """True if completion will arrive via ``_complete`` without polling."""
        return False

    # -- hooks ------------------------------------------------------------------
    def add_ready_hook(self, hook: ReadyHook) -> None:
        """Run ``hook`` on completion; immediately if already complete."""
        run_now = False
        with self._hook_lock:
            if self._state is OpState.PENDING and not self._latch.fired:
                self._hooks.append(hook)
            else:
                run_now = True
        if run_now:
            hook(self, self._status)

    # -- cancellation ------------------------------------------------------------
    def cancel(self) -> bool:
        """Best-effort cancel; True if the op transitioned to CANCELLED."""
        return self._complete(Status(cancelled=True), OpState.CANCELLED)

    # -- attachment bookkeeping ---------------------------------------------------
    def mark_attached(self) -> None:
        if self._attached and not self.persistent:
            raise RuntimeError(
                "operation already has a continuation attached; non-persistent "
                "handles are consumed on attach (paper §2.2)")
        self._attached = True

    def release_attachment(self) -> None:
        self._attached = False


def _tree_leaves(tree: Any) -> Sequence[Any]:
    import jax
    return jax.tree_util.tree_leaves(tree)


class ArrayOp(Completable):
    """Completion of JAX async dispatch for a pytree of ``jax.Array``.

    Poll-mode by default (JAX has no completion callback API); an engine
    waiter thread can block on it when the CR allows ``thread=any``.
    """

    def __init__(self, tree: Any, payload: Any = None) -> None:
        super().__init__()
        self._leaves = [x for x in _tree_leaves(tree) if hasattr(x, "is_ready")]
        self._status.payload = tree if payload is None else payload

    def _poll(self) -> bool:
        while self._leaves and self._leaves[-1].is_ready():
            self._leaves.pop()
        return not self._leaves

    def block(self) -> None:
        """Blocking wait used by waiter threads (push emulation)."""
        import jax
        for leaf in self._leaves:
            jax.block_until_ready(leaf)
        self._leaves = []
        self.done()


class HostTaskOp(Completable):
    """Completion of a ``concurrent.futures.Future`` — push-notified."""

    def __init__(self, future: Future) -> None:
        super().__init__()
        self._future = future
        future.add_done_callback(self._on_done)

    @property
    def supports_push(self) -> bool:
        return True

    def _on_done(self, fut: Future) -> None:
        if fut.cancelled():
            self._complete(Status(cancelled=True), OpState.CANCELLED)
            return
        err = fut.exception()
        if err is not None:
            self._complete(Status(error=err), OpState.FAILED)
        else:
            self._complete(Status(payload=fut.result()))

    def _poll(self) -> bool:  # completion arrives via _on_done
        return self._future.done()

    def cancel(self) -> bool:
        self._future.cancel()  # _on_done publishes the transition
        return self._state is OpState.CANCELLED


class TimerOp(Completable):
    """Completes once ``deadline`` (monotonic seconds) has passed."""

    def __init__(self, delay_s: float) -> None:
        super().__init__()
        self.deadline = time.monotonic() + delay_s

    def _poll(self) -> bool:
        return time.monotonic() >= self.deadline


class PredicateOp(Completable):
    """Completes when a user-supplied predicate returns True."""

    def __init__(self, predicate: Callable[[], bool], payload: Any = None) -> None:
        super().__init__()
        self._predicate = predicate
        self._status.payload = payload

    def _poll(self) -> bool:
        return bool(self._predicate())


# --------------------------------------------------------------- combinators
class CombinedOp(Completable):
    """Composite op: completes when ``k`` of ``n`` child ops have completed.

    Construction *consumes* the children (ownership rule). When the k-th
    child completes ("the win"):

    * ``indices`` holds the winning child indices in completion order and
      ``op_statuses[i]`` the winners' statuses (loser slots stay ``None``);
    * every loser's handle is released back to the caller (and best-effort
      cancelled when ``cancel_losers=True``);
    * late loser completions are ignored — the composite can never fire
      twice.

    The composite's own status: ``payload`` shape follows ``mode`` —
    ``"all"`` gives the child-ordered payload list, ``"any"`` the single
    winner's payload, ``"some"`` ``(index, payload)`` pairs in completion
    order. The helpers pin their mode (so ``when_any([op])`` still yields
    the bare winner payload at ``n == 1``); a direct ``CombinedOp``
    construction infers ``all``/``any``/``some`` from ``k`` vs ``n``. The
    first winner error (or cancellation) propagates, so a failed child
    rejects a promise chained on the composite.

    An empty group with ``k == 0`` (``when_all([])``) completes vacuously
    at construction with an empty payload — mirroring
    ``continue_all([], ...)``'s immediate-completion contract.
    """

    def __init__(self, ops: Sequence["Completable"], k: int, *,
                 cancel_losers: bool = False,
                 mode: Optional[str] = None) -> None:
        super().__init__()
        n = len(ops)
        if n == 0:
            if k != 0:
                raise ValueError(f"need k == 0 for an empty group, got {k}")
        elif not 1 <= k <= n:
            raise ValueError(f"need 1 <= k <= {n} ops, got k={k}")
        if mode is None:
            mode = "all" if k == n else "any" if k == 1 else "some"
        if mode not in ("all", "any", "some"):
            raise ValueError(f"unknown combinator mode {mode!r}")
        self._mode = mode
        self._ops = list(ops)
        self._k = k
        self._cancel_losers = cancel_losers
        self._comb_lock = threading.Lock()
        self._won = False
        self.indices: list[int] = []
        self.op_statuses: list[Optional[Status]] = [None] * n
        marked = []
        try:
            for op in self._ops:
                op.mark_attached()
                marked.append(op)
        except BaseException:
            # same rollback contract as Engine.continue_all: a failed
            # construction must not leave the prefix consumed
            for op in marked:
                op.release_attachment()
            raise
        if n == 0:
            self._won = True             # vacuous completion
            self._complete(Status(payload=[]))
            return
        for i, op in enumerate(self._ops):
            op.add_ready_hook(self._child_hook(i))

    def _child_hook(self, index: int):
        def _hook(op: "Completable", status: Status, _i: int = index) -> None:
            self._child_done(_i, status)
        return _hook

    def _child_done(self, i: int, status: Status) -> None:
        with self._comb_lock:
            if self._won:
                return                 # late loser — ignored
            self.op_statuses[i] = status
            self.indices.append(i)
            if len(self.indices) < self._k:
                return
            self._won = True
        self._finish()

    def _finish(self) -> None:
        losers = [op for j, op in enumerate(self._ops)
                  if self.op_statuses[j] is None]
        for op in losers:
            op.release_attachment()
            if self._cancel_losers:
                op.cancel()            # their hooks see _won and no-op
        won = [self.op_statuses[i] for i in self.indices]
        error = next((s.error for s in won if s.error is not None), None)
        cancelled = any(s.cancelled for s in won)
        if self._mode == "all":
            payload = [s.payload for s in self.op_statuses]
        elif self._mode == "any":
            payload = won[0].payload
        else:                             # "some"
            payload = [(i, self.op_statuses[i].payload) for i in self.indices]
        state = (OpState.FAILED if error is not None
                 else OpState.CANCELLED if cancelled else OpState.COMPLETE)
        self._complete(Status(error=error, cancelled=cancelled,
                              payload=payload), state)

    @property
    def supports_push(self) -> bool:
        return all(op.supports_push for op in self._ops)

    def _poll(self) -> bool:
        # Drive pending poll-mode children; completion happens through the
        # child hooks (idempotent against the race with a push child).
        for op in self._ops:
            if self._won:
                break
            if op.state is OpState.PENDING:
                op.done()
        return self._won

    def cancel(self) -> bool:
        with self._comb_lock:
            if self._won:
                return False
            self._won = True           # block child hooks from firing us
        for j, op in enumerate(self._ops):
            if self.op_statuses[j] is None:
                op.release_attachment()
                op.cancel()
        return self._complete(Status(cancelled=True), OpState.CANCELLED)

    def detach(self) -> None:
        """Neutralize the composite: ignore every future child completion.

        Used by registration rollback — ``Completable`` has no hook
        removal, so after the children are handed back to the caller the
        orphaned composite must never release/cancel them out from under
        a later registration. The composite itself never completes.
        """
        with self._comb_lock:
            self._won = True


def when_all(ops: Sequence["Completable"]) -> CombinedOp:
    """Composite completing when ALL of ``ops`` complete (payload = child
    payload list in op order; an empty group completes vacuously)."""
    return CombinedOp(ops, len(ops), mode="all")


def when_any(ops: Sequence["Completable"], *,
             cancel_losers: bool = False) -> CombinedOp:
    """Composite completing when ANY child completes (payload = winner's
    payload, regardless of group size; ``.indices[0]`` names the winner)."""
    return CombinedOp(ops, 1, cancel_losers=cancel_losers, mode="any")


def when_some(ops: Sequence["Completable"], k: int, *,
              cancel_losers: bool = False) -> CombinedOp:
    """Composite completing when ``k`` children have completed
    (``MPI_Waitsome`` analogue; payload = ``(index, payload)`` pairs in
    completion order — see ``CombinedOp`` for indices/statuses)."""
    return CombinedOp(ops, k, cancel_losers=cancel_losers, mode="some")
