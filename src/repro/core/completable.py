"""Completable operations — what continuations can be attached to.

In MPI the unit of asynchrony is the request; in a JAX framework it is
anything that completes out-of-line with the control thread:

* ``ArrayOp``     — a (pytree of) ``jax.Array``; complete when dispatch has
                    finished materializing every leaf (``Array.is_ready()``).
* ``HostTaskOp``  — a ``concurrent.futures.Future`` (checkpoint shard writes,
                    data-pipeline fills, metric fetches). Push-notified.
* ``TimerOp``     — completes at a deadline (heartbeat/straggler timeouts).
* ``PredicateOp`` — completes when a user predicate flips true.
* ``MessageOp``   — transport send/recv handles (see ``transport.py``).
* ``ContinuationRequest`` — CRs are completable themselves (paper §3.2:
  a continuation may be attached to a CR and registered with another CR).

Ops follow the paper's ownership rule: attaching a continuation *consumes*
the handle (at most one continuation per op; re-attach only for persistent
ops after restart).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Optional, Sequence

from repro.core.status import OneShotLatch, OpState, Status

ReadyHook = Callable[["Completable", Status], None]


class Completable:
    """Base class for asynchronous operations.

    Subclasses either support *polling* (override ``_poll``) or *push*
    notification (call ``_complete`` from wherever the work finishes), or
    both. The continuation engine uses push hooks when available and falls
    back to polling scans during progress calls — mirroring an MPI library
    discovering completions inside any MPI call.
    """

    #: persistent ops may be restarted and re-attached (MPI persistent reqs)
    persistent: bool = False

    def __init__(self) -> None:
        self._latch = OneShotLatch()
        self._state = OpState.PENDING
        self._status = Status()
        self._hooks: list[ReadyHook] = []
        self._hook_lock = threading.Lock()
        self._attached = False

    # -- completion publishing ------------------------------------------------
    def _complete(self, status: Optional[Status] = None,
                  state: OpState = OpState.COMPLETE) -> bool:
        """Publish completion exactly once; fire hooks on the caller thread."""
        if not self._latch.fire():
            return False
        if status is not None:
            self._status = status
        self._state = state
        if state == OpState.CANCELLED:
            self._status.cancelled = True
        with self._hook_lock:
            hooks, self._hooks = list(self._hooks), []
        for hook in hooks:
            hook(self, self._status)
        return True

    # -- probing ----------------------------------------------------------------
    def _poll(self) -> bool:
        """Subclass probe: return True when the underlying work is done.

        Only called while PENDING; must be cheap and non-blocking.
        """
        return False

    def done(self) -> bool:
        """Non-blocking completion test (drives poll-mode ops forward)."""
        if self._state is not OpState.PENDING:
            return True
        if self._poll():
            self._complete(self._make_status())
            return True
        return False

    def _make_status(self) -> Status:
        return self._status

    # -- introspection ---------------------------------------------------------
    @property
    def state(self) -> OpState:
        return self._state

    @property
    def status(self) -> Status:
        return self._status

    @property
    def supports_push(self) -> bool:
        """True if completion will arrive via ``_complete`` without polling."""
        return False

    # -- hooks ------------------------------------------------------------------
    def add_ready_hook(self, hook: ReadyHook) -> None:
        """Run ``hook`` on completion; immediately if already complete."""
        run_now = False
        with self._hook_lock:
            if self._state is OpState.PENDING and not self._latch.fired:
                self._hooks.append(hook)
            else:
                run_now = True
        if run_now:
            hook(self, self._status)

    # -- cancellation ------------------------------------------------------------
    def cancel(self) -> bool:
        """Best-effort cancel; True if the op transitioned to CANCELLED."""
        return self._complete(Status(cancelled=True), OpState.CANCELLED)

    # -- attachment bookkeeping ---------------------------------------------------
    def mark_attached(self) -> None:
        if self._attached and not self.persistent:
            raise RuntimeError(
                "operation already has a continuation attached; non-persistent "
                "handles are consumed on attach (paper §2.2)")
        self._attached = True

    def release_attachment(self) -> None:
        self._attached = False


def _tree_leaves(tree: Any) -> Sequence[Any]:
    import jax
    return jax.tree_util.tree_leaves(tree)


class ArrayOp(Completable):
    """Completion of JAX async dispatch for a pytree of ``jax.Array``.

    Poll-mode by default (JAX has no completion callback API); an engine
    waiter thread can block on it when the CR allows ``thread=any``.
    """

    def __init__(self, tree: Any, payload: Any = None) -> None:
        super().__init__()
        self._leaves = [x for x in _tree_leaves(tree) if hasattr(x, "is_ready")]
        self._status.payload = tree if payload is None else payload

    def _poll(self) -> bool:
        while self._leaves and self._leaves[-1].is_ready():
            self._leaves.pop()
        return not self._leaves

    def block(self) -> None:
        """Blocking wait used by waiter threads (push emulation)."""
        import jax
        for leaf in self._leaves:
            jax.block_until_ready(leaf)
        self._leaves = []
        self.done()


class HostTaskOp(Completable):
    """Completion of a ``concurrent.futures.Future`` — push-notified."""

    def __init__(self, future: Future) -> None:
        super().__init__()
        self._future = future
        future.add_done_callback(self._on_done)

    @property
    def supports_push(self) -> bool:
        return True

    def _on_done(self, fut: Future) -> None:
        if fut.cancelled():
            self._complete(Status(cancelled=True), OpState.CANCELLED)
            return
        err = fut.exception()
        if err is not None:
            self._complete(Status(error=err), OpState.FAILED)
        else:
            self._complete(Status(payload=fut.result()))

    def _poll(self) -> bool:  # completion arrives via _on_done
        return self._future.done()

    def cancel(self) -> bool:
        self._future.cancel()  # _on_done publishes the transition
        return self._state is OpState.CANCELLED


class TimerOp(Completable):
    """Completes once ``deadline`` (monotonic seconds) has passed."""

    def __init__(self, delay_s: float) -> None:
        super().__init__()
        self.deadline = time.monotonic() + delay_s

    def _poll(self) -> bool:
        return time.monotonic() >= self.deadline


class PredicateOp(Completable):
    """Completes when a user-supplied predicate returns True."""

    def __init__(self, predicate: Callable[[], bool], payload: Any = None) -> None:
        super().__init__()
        self._predicate = predicate
        self._status.payload = payload

    def _poll(self) -> bool:
        return bool(self._predicate())
