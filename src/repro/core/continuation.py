"""Continuations and continuation requests (paper §2–3).

A ``Continuation`` = callback *body* + *context* (``cb_data``) attached to one
or more active operations (``continue_when`` / ``continue_all``); it becomes
*ready* when the last of its operations completes and is *executed* exactly
once, after which it is deregistered from its ``ContinuationRequest``.

``ContinuationRequest`` (CR) is the persistent-request-like aggregator with
the Fig. 1 state machine::

    INITIALIZED/ INACTIVE --register--> ACTIVE_REFERENCED
    ACTIVE_REFERENCED --last deregistered--> ACTIVE_IDLE
    ACTIVE_IDLE --register--> ACTIVE_REFERENCED
    ACTIVE_IDLE --completion call (test/wait)--> COMPLETE
    COMPLETE --register--> ACTIVE_REFERENCED
    any active state --free()--> released once the set drains

Thread-safety contract (paper §3.3): any number of threads may register
concurrently; at most one thread may test/wait a given CR at a time (we
detect violations and raise). Callbacks never run nested inside other
callbacks (paper §3.1).

Per-registration control (the API-redesign layer, ``core.flags``): each
``Continuation`` carries a ``ResolvedPolicy`` — the CR's ``ContinueInfo``
defaults overridden by any ``ContinueFlags`` passed at registration — so
routing (poll_only queue vs scheduler), thread eligibility, inline
execution, and error surfacing are decided per registration, not per CR.
"""
from __future__ import annotations

import collections
import enum
import threading
from typing import Any, Callable, List, Optional, Sequence

from repro.core.completable import Completable
from repro.core.flags import ResolvedPolicy, resolve
from repro.core.info import ContinueInfo, make_info
from repro.core.status import OpState, Status
from repro.obs import events as _obs_events
from repro.obs import tracer as _obs

# Callback signature mirrors MPIX_Continue_cb_function(statuses, cb_data).
ContinueCallback = Callable[[Optional[List[Status]], Any], None]


class CRState(enum.Enum):
    INACTIVE = "inactive"            # initialized, nothing ever registered
    ACTIVE_REFERENCED = "active_referenced"
    ACTIVE_IDLE = "active_idle"
    COMPLETE = "complete"
    FREED = "freed"                  # free() called; released when drained


class ConcurrentCompletionError(RuntimeError):
    """Two threads tested/waited the same CR simultaneously (paper §3.3)."""


class CallbackError(RuntimeError):
    """A continuation callback raised; re-raised from test/wait (on_error="raise")."""


class ContinuationState(enum.Enum):
    WAITING = "waiting"    # some ops outstanding
    READY = "ready"        # all ops complete, callback not yet run
    RUNNING = "running"
    DONE = "done"


class ClassDeque:
    """Ready-queue primitive shared by every continuation queue: two FIFO
    deques split by priority class. ``priority > 0`` registrations drain
    first but stay FIFO *within* their class — priority jumping must
    never reorder continuations from the same source (e.g. a serve
    request's consecutive step completions), which a naive
    ``appendleft`` would turn LIFO. Not thread-safe: callers hold their
    own lock.
    """

    __slots__ = ("hi", "lo")

    def __init__(self) -> None:
        self.hi: collections.deque["Continuation"] = collections.deque()
        self.lo: collections.deque["Continuation"] = collections.deque()

    def _class(self, cont: "Continuation") -> collections.deque:
        return self.hi if cont.policy.priority > 0 else self.lo

    def push(self, cont: "Continuation") -> None:
        self._class(cont).append(cont)

    def push_front(self, cont: "Continuation") -> None:
        """Requeue at the head of the continuation's class."""
        self._class(cont).appendleft(cont)

    def pop(self) -> Optional["Continuation"]:
        if self.hi:
            return self.hi.popleft()
        if self.lo:
            return self.lo.popleft()
        return None

    def __len__(self) -> int:
        return len(self.hi) + len(self.lo)

    def __bool__(self) -> bool:
        return bool(self.hi) or bool(self.lo)


class Continuation:
    """One registered callback, possibly spanning several operations."""

    __slots__ = ("cb", "cb_data", "ops", "statuses", "cr", "policy",
                 "_remaining", "_lock", "state", "seqno",
                 "t_posted", "t_ready", "t_enqueued")

    def __init__(self, cb: ContinueCallback, cb_data: Any,
                 ops: Sequence[Completable],
                 statuses: Optional[List[Status]],
                 cr: "ContinuationRequest",
                 policy: Optional[ResolvedPolicy] = None) -> None:
        self.cb = cb
        self.cb_data = cb_data
        self.ops = list(ops)
        # volatile_statuses: the caller's list may be reused immediately
        # after registration — snapshot into an engine-owned list that the
        # callback receives instead.
        self.policy = policy if policy is not None else resolve(cr.info, None)
        if self.policy.volatile_statuses and statuses is not None:
            statuses = list(statuses)
        self.statuses = statuses
        self.cr = cr
        self._remaining = len(ops)
        self._lock = threading.Lock()
        self.state = ContinuationState.WAITING
        self.seqno = 0  # set by the engine; FIFO fairness in ready queues
        # lifecycle-edge trace stamps; ``t_posted is not None`` == this
        # continuation was sampled at registration (obs.tracer)
        self.t_posted = None
        self.t_ready = None
        self.t_enqueued = None

    def _op_done(self, index: int, status: Status) -> None:
        """Hook target: operation ``index`` completed with ``status``."""
        ready = False
        with self._lock:
            if self.statuses is not None:
                self.statuses[index] = status
            self._remaining -= 1
            if self._remaining == 0 and self.state is ContinuationState.WAITING:
                self.state = ContinuationState.READY
                ready = True
        if ready:
            # lifecycle edge 2/4: the op group completed (WAITING -> READY)
            if self.t_posted is not None:
                tr = _obs.TRACE
                if tr is not None:
                    self.t_ready = ts = tr.now()
                    tr.evt(_obs_events.CONT_READY, self.seqno, "core", ts=ts)
            self.cr._continuation_ready(self)

    def hook_for(self, index: int):
        def _hook(op: Completable, status: Status, _i: int = index) -> None:
            self._op_done(_i, status)
        return _hook

    def run(self) -> Optional[BaseException]:
        """Execute the callback; returns the exception if one was raised."""
        self.state = ContinuationState.RUNNING
        try:
            self.cb(self.statuses, self.cb_data)
            return None
        except BaseException as exc:  # surfaced via CR error policy
            return exc
        finally:
            self.state = ContinuationState.DONE


class ContinuationRequest(Completable):
    """Aggregates active continuations; testable/waitable; itself completable.

    Create via ``Engine.continue_init`` (the ``MPIX_Continue_init`` analogue).
    """

    def __init__(self, engine, info: Optional[ContinueInfo] = None) -> None:
        super().__init__()
        self.engine = engine
        self.info = info if isinstance(info, ContinueInfo) else make_info(info)
        self.cr_state = CRState.INACTIVE
        self._active = 0                   # registered & not yet executed
        self._lock = threading.RLock()
        self._idle_cond = threading.Condition(self._lock)
        # ready-but-not-executed continuations for poll_only CRs; non-poll_only
        # CRs route ready continuations to the engine's shared queue.
        self._ready_q = ClassDeque()
        self._errors: list[BaseException] = []
        self._raise_q: list[BaseException] = []   # subset with on_error=raise
        self._released = False                    # free() fully drained
        self._tester: Optional[int] = None   # thread id currently in test/wait
        # one-shot "drained" observers (CR-as-completable chaining)
        self._empty_hooks: list[Callable[[], None]] = []
        self.stats = {"registered": 0, "executed": 0, "immediate": 0}

    # ------------------------------------------------------------------ state
    @property
    def active_count(self) -> int:
        return self._active

    def _register(self, count: int = 1) -> None:
        with self._lock:
            if self.cr_state is CRState.FREED:
                raise RuntimeError("cannot register continuations on a freed CR")
            self._active += count
            self.cr_state = CRState.ACTIVE_REFERENCED
            self.stats["registered"] += count

    def _continuation_ready(self, cont: Continuation) -> None:
        """Routing, resolved per registration: poll_only continuations go
        to this CR's private queue; others to the engine's scheduler (which
        may execute inline when the continuation's policy allows)."""
        # lifecycle edge 3/4: enqueued on a ready queue (either route)
        if cont.t_posted is not None:
            tr = _obs.TRACE
            if tr is not None:
                cont.t_enqueued = ts = tr.now()
                tr.evt(_obs_events.CONT_ENQUEUED, cont.seqno, "core", ts=ts)
        if cont.policy.poll_only:
            with self._lock:
                self._ready_q.push(cont)
        else:
            self.engine.scheduler.submit(cont)

    def _deregister(self, error: Optional[BaseException],
                    policy: Optional["ResolvedPolicy"] = None) -> None:
        """Called by the engine after a continuation executed.

        ``policy`` carries the registration's error policy; ``None`` falls
        back to the CR info default (pre-flags callers).
        """
        hooks: list[Callable[[], None]] = []
        on_error = self.info.on_error if policy is None else policy.on_error
        handler = on_error if callable(on_error) else None
        with self._lock:
            self._active -= 1
            self.stats["executed"] += 1
            if error is not None and handler is None:
                self._errors.append(error)
                if on_error == "raise":
                    self._raise_q.append(error)
            if self._active == 0:
                if self.cr_state is not CRState.FREED:
                    self.cr_state = CRState.ACTIVE_IDLE
                elif not self._released:
                    self._released = True
                hooks, self._empty_hooks = self._empty_hooks, []
                self._idle_cond.notify_all()
        if error is not None and handler is not None:
            try:
                handler(error)
            except Exception:
                with self._lock:       # a broken handler must not vanish
                    self._errors.append(error)
        for hook in hooks:
            hook()

    def _raise_pending_errors(self) -> None:
        if self._raise_q:
            with self._lock:
                errs, self._raise_q = self._raise_q, []
                raise_set = set(map(id, errs))
                self._errors = [e for e in self._errors
                                if id(e) not in raise_set]
            raise CallbackError(
                f"{len(errs)} continuation callback(s) raised; first error "
                f"follows") from errs[0]

    @property
    def errors(self) -> list[BaseException]:
        return list(self._errors)

    # --------------------------------------------------------------- test/wait
    def _acquire_tester(self) -> None:
        me = threading.get_ident()
        with self._lock:
            if self._tester is not None and self._tester != me:
                raise ConcurrentCompletionError(
                    "only one thread may test/wait a CR at a time (paper §3.3)")
            self._tester = me

    def _release_tester(self) -> None:
        with self._lock:
            self._tester = None

    def test(self) -> bool:
        """``MPI_Test`` analogue: progress + run eligible callbacks.

        Returns True iff no active continuations remain registered.
        """
        self._acquire_tester()
        try:
            self.engine._progress_for_test(self)
            with self._lock:
                flag = self._active == 0
                if flag and self.cr_state in (CRState.ACTIVE_IDLE, CRState.INACTIVE):
                    self.cr_state = CRState.COMPLETE
            self._raise_pending_errors()
            return flag
        finally:
            self._release_tester()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """``MPI_Wait`` analogue: block until all registered continuations ran."""
        import time
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self.test():
                return True
            if deadline is not None and time.monotonic() >= deadline:
                return False
            # Block briefly; woken early when the active set drains. We still
            # loop to progress poll-mode ops that need scanning.
            with self._idle_cond:
                if self._active:
                    self._idle_cond.wait(timeout=self.engine.wait_poll_interval)

    def free(self) -> None:
        """``MPI_Request_free`` analogue.

        Drain contract: freeing a CR forbids *new* registrations but lets
        already-registered continuations run; the CR is *released* when the
        active set drains. A CR whose active set is already empty releases
        immediately — ``free()`` on an idle (or never-used) CR must not
        leave it waiting for a drain that will never happen.
        """
        hooks: list[Callable[[], None]] = []
        with self._lock:
            self.cr_state = CRState.FREED
            if self._active == 0 and not self._released:
                self._released = True
                hooks, self._empty_hooks = self._empty_hooks, []
                self._idle_cond.notify_all()
        for hook in hooks:
            hook()

    @property
    def released(self) -> bool:
        """True once ``free()`` was called and the active set has drained
        (immediately, if it was already empty)."""
        with self._lock:
            return self._released

    # ------------------------------------------------- CR as completable (op)
    # Attaching a continuation to a CR (paper §3.2) observes "the active set
    # became empty". One-shot, like any operation.
    def _poll(self) -> bool:
        with self._lock:
            return self._active == 0

    def add_ready_hook(self, hook) -> None:
        # Push path: notify when drained; immediate if already idle.
        with self._lock:
            if self._active:
                self._empty_hooks.append(lambda: hook(self, self._status))
                return
        hook(self, self._status)

    @property
    def supports_push(self) -> bool:
        return True

    def cancel(self) -> bool:  # CRs cannot be cancelled
        return False
