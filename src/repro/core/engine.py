"""The continuation engine facade — registration + policy wiring.

Execution model (paper §2–3), now split across three components:

* **Registration** (this module, ``continue_when`` / ``continue_all``):
  attach a callback to active op(s); if *all* are already complete and the
  CR does not set ``enqueue_complete``, return ``flag=True`` *without*
  invoking the callback (immediate-completion fast path, paper §2.2).
  Otherwise the continuation is registered with the CR and hooks are
  installed on each op.

* **Discovery** (``core.progress.Progress``): push-capable ops (host
  futures, transport messages, CRs) publish completion from whatever thread
  finished the work. Poll-mode ops (``jax.Array``) are discovered by
  progress scans: every engine entry point (``tick``, ``cr.test/wait``,
  transport calls) advances the scan, and an optional internal progress
  thread does too. CRs with ``thread="any"`` may additionally hand array
  ops to *waiter threads* that block on readiness.

* **Execution** (``core.scheduler.Scheduler``): a ready continuation runs
  (a) inline on the discovering thread when policy allows (not poll_only;
  thread policy admits the current thread; not nested inside another
  callback — paper §3.1), else (b) from the scheduler's ready queue(s) at
  the next engine entry of an eligible thread, else (c) for poll_only CRs,
  only inside ``cr.test()`` — bounded by ``max_poll``.

``Engine`` wires a ``Scheduler`` (pluggable: ``"fifo"`` shared-deque FIFO
or ``"affinity"`` per-thread queues with stealing) to a ``Progress``
instance and exposes the paper's public API: ``continue_init``,
``continue_when``, ``continue_all``, ``tick``, and CR ``test/wait/free``.
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, List, Optional, Sequence, Union

from repro.core.completable import Completable
from repro.core.continuation import Continuation, ContinuationRequest
from repro.core.info import THREAD_ANY, ContinueInfo, make_info
from repro.core.progress import Progress
from repro.core.scheduler import (Scheduler, in_callback, in_registration,
                                  make_scheduler, registration_guard)
from repro.core.status import Status

# Back-compat aliases: these lived here before the scheduler split.
_in_callback = in_callback
_in_registration = in_registration


class Engine:
    """A continuations runtime instance. One per process is typical
    (``default_engine()``), but apps may build isolated engines (tests do).
    """

    def __init__(self, *, scheduler: Union[str, Scheduler] = "fifo",
                 progress_thread: bool = False,
                 progress_interval: float = 2e-4,
                 n_waiters: int = 0,
                 inline_limit: int = 16,
                 wait_poll_interval: float = 5e-4) -> None:
        self.scheduler = make_scheduler(scheduler, inline_limit=inline_limit)
        self.progress = Progress(self.scheduler,
                                 progress_thread=progress_thread,
                                 progress_interval=progress_interval,
                                 n_waiters=n_waiters)
        self._seq = itertools.count()
        self.wait_poll_interval = wait_poll_interval
        self._progress_calls = 0

    @property
    def inline_limit(self) -> int:
        return self.scheduler.inline_limit

    @inline_limit.setter
    def inline_limit(self, value: int) -> None:
        self.scheduler.inline_limit = value

    @property
    def stats(self) -> dict:
        """Merged component counters (kept flat for existing consumers)."""
        out = {"progress_calls": self._progress_calls}
        out.update(self.scheduler.stats)
        out.update(self.progress.stats)
        return out

    # ------------------------------------------------------------------ setup
    def continue_init(self, info: Optional[Union[dict, ContinueInfo]] = None,
                      **kwargs: Any) -> ContinuationRequest:
        """``MPIX_Continue_init`` analogue."""
        if isinstance(info, ContinueInfo):
            cinfo = info
        else:
            cinfo = make_info(info, **kwargs)
        cr = ContinuationRequest(self, cinfo)
        cr.persistent = True  # CRs are persistent-request-like
        return cr

    # ------------------------------------------------------------ registration
    def continue_when(self, op: Completable, cb, cb_data: Any = None,
                      status: Optional[List[Status]] = None,
                      cr: Optional[ContinuationRequest] = None) -> bool:
        """``MPIX_Continue`` analogue. Returns the immediate-completion flag."""
        return self.continue_all([op], cb, cb_data, statuses=status, cr=cr)

    def continue_all(self, ops: Sequence[Completable], cb, cb_data: Any = None,
                     statuses: Optional[List[Status]] = None,
                     cr: Optional[ContinuationRequest] = None) -> bool:
        """``MPIX_Continueall`` analogue.

        ``statuses``: None (= MPI_STATUSES_IGNORE) or a caller-allocated list
        of length ``len(ops)`` that is written before the callback runs (or
        before return on immediate completion).
        """
        if cr is None:
            raise ValueError("a ContinuationRequest is required")
        if statuses is not None and len(statuses) != len(ops):
            raise ValueError("statuses list must match ops length")
        for op in ops:
            op.mark_attached()

        # Immediate-completion fast path: drive each op's probe once.
        if not cr.info.enqueue_complete and all(op.done() for op in ops):
            if statuses is not None:
                for i, op in enumerate(ops):
                    statuses[i] = op.status
            cr.stats["immediate"] += 1
            return True

        cont = Continuation(cb, cb_data, ops, statuses, cr)
        cont.seqno = next(self._seq)
        cr._register()
        needs_scan = []
        # Callbacks are never invoked from within continue_[all] itself —
        # registration may happen inside an application critical region
        # (paper §3.1) — so inline execution is suppressed while hooks are
        # installed; a ready continuation lands on the scheduler instead.
        with registration_guard():
            for i, op in enumerate(ops):
                if not op.supports_push and op.state.name == "PENDING":
                    needs_scan.append(op)
                # Hooks fire inline for already-complete ops, so mixed
                # immediate/pending groups resolve correctly.
                op.add_ready_hook(cont.hook_for(i))
        if needs_scan:
            hand_to_waiters = (cr.info.thread == THREAD_ANY
                               and self.progress.has_waiters)
            for op in needs_scan:
                self.progress.watch(op, use_waiter=hand_to_waiters)
        return False

    # -------------------------------------------------------------- progress
    def tick(self) -> None:
        """Generic progress: discover + run eligible ready continuations.

        The analogue of "an application thread called into MPI".
        """
        self._progress_calls += 1
        self.progress.scan()
        self.scheduler.drain()

    def enter(self) -> None:
        """Lightweight entry hook: run eligible ready continuations inline.

        Transport (and other substrates) call this on every operation — the
        analogue of "continuations may be invoked as soon as any thread
        calls into MPI" (paper §3) — without paying for a full poll scan.
        """
        self.scheduler.drain(limit=self.scheduler.inline_limit, inline=True)

    def _progress_for_test(self, cr: ContinuationRequest) -> None:
        """Progress driven by ``cr.test()``: bounded by the CR's max_poll."""
        self._progress_calls += 1
        self.progress.scan()
        budget = cr.info.max_poll
        if cr.info.poll_only:
            # Other CRs' callbacks still run (we are an application thread
            # inside the engine) — but this CR's run only here, capped.
            self.scheduler.drain_cr_queue(cr, budget)
            self.scheduler.drain()
        else:
            self.scheduler.drain(for_cr=cr, cr_limit=budget)

    # ------------------------------------------------ back-compat delegates
    # Pre-split internal entry points; substrate code now uses the
    # components directly, but external callers may still poke these.
    def _enqueue_ready(self, cont: Continuation) -> None:
        self.scheduler.submit(cont)

    def _drain_ready(self, limit: int = -1, inline: bool = False,
                     for_cr: Optional[ContinuationRequest] = None,
                     cr_limit: int = -1) -> int:
        return self.scheduler.drain(limit=limit, inline=inline,
                                    for_cr=for_cr, cr_limit=cr_limit)

    def _scan_polls(self) -> None:
        self.progress.scan()

    # -------------------------------------------------------------- lifecycle
    def shutdown(self) -> None:
        self.progress.shutdown()

    def register_internal_thread(self) -> None:
        """Mark the calling thread as engine-internal (thread=any gating)."""
        self.scheduler.register_internal_thread()


_default_engine: Optional[Engine] = None
_default_lock = threading.Lock()


def default_engine() -> Engine:
    global _default_engine
    with _default_lock:
        if _default_engine is None:
            _default_engine = Engine()
        return _default_engine


def reset_default_engine() -> None:
    global _default_engine
    with _default_lock:
        if _default_engine is not None:
            _default_engine.shutdown()
        _default_engine = None
