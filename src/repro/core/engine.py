"""The continuation engine facade — registration + policy wiring.

Execution model (paper §2–3), now split across three components:

* **Registration** (this module, ``continue_when`` / ``continue_all`` /
  ``continue_any`` / ``continue_some``): attach a callback to active
  op(s); if the group already satisfies its completion condition and the
  registration's *resolved policy* (CR ``ContinueInfo`` defaults
  overridden by per-registration ``ContinueFlags``) does not set
  ``enqueue_complete``, return ``flag=True`` *without* invoking the
  callback (immediate-completion fast path, paper §2.2). Otherwise the
  continuation is registered with the CR and hooks are installed on each
  op. All control knobs — fast path, routing, inline eligibility, thread
  and error policy — resolve per registration (``core.flags``); CR info
  keys are just the defaults.

* **Discovery** (``core.progress.Progress``): push-capable ops (host
  futures, transport messages, CRs) publish completion from whatever thread
  finished the work. Poll-mode ops (``jax.Array``) are discovered by
  progress scans: every engine entry point (``tick``, ``cr.test/wait``,
  transport calls) advances the scan, and an optional internal progress
  thread does too. CRs with ``thread="any"`` may additionally hand array
  ops to *waiter threads* that block on readiness.

* **Execution** (``core.scheduler.Scheduler``): a ready continuation runs
  (a) inline on the discovering thread when its resolved policy allows
  (not poll_only or defer_complete; thread policy admits the current
  thread; not nested inside another callback — paper §3.1), else (b) from
  the scheduler's ready queue(s) at the next engine entry of an eligible
  thread, else (c) for poll_only registrations, only inside ``cr.test()``
  — bounded by the CR's ``max_poll``.

``Engine`` wires a ``Scheduler`` (pluggable: ``"fifo"`` shared-deque FIFO
or ``"affinity"`` per-thread queues with stealing) to a ``Progress``
instance and exposes the paper's public API: ``continue_init``,
``continue_when``, ``continue_all``, ``tick``, and CR ``test/wait/free``.
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, List, Optional, Sequence, Union

from repro.core.completable import Completable, when_some
from repro.core.continuation import Continuation, ContinuationRequest
from repro.core.flags import ContinueFlags, make_flags, resolve
from repro.core.info import THREAD_ANY, ContinueInfo, make_info
from repro.core.progress import Progress
from repro.core.scheduler import (Scheduler, in_callback, in_registration,
                                  make_scheduler, registration_guard)
from repro.core.status import Status
from repro.obs import events as _obs_events
from repro.obs import tracer as _obs

# Back-compat aliases: these lived here before the scheduler split.
_in_callback = in_callback
_in_registration = in_registration


class Engine:
    """A continuations runtime instance. One per process is typical
    (``default_engine()``), but apps may build isolated engines (tests do).
    """

    def __init__(self, *, scheduler: Union[str, Scheduler] = "fifo",
                 progress_thread: bool = False,
                 progress_interval: float = 2e-4,
                 n_waiters: int = 0,
                 inline_limit: int = 16,
                 wait_poll_interval: float = 5e-4) -> None:
        self.scheduler = make_scheduler(scheduler, inline_limit=inline_limit)
        self.progress = Progress(self.scheduler,
                                 progress_thread=progress_thread,
                                 progress_interval=progress_interval,
                                 n_waiters=n_waiters)
        self._seq = itertools.count()
        self.wait_poll_interval = wait_poll_interval
        self._progress_calls = 0
        self._promise_cr: Optional[ContinuationRequest] = None
        self._promise_cr_lock = threading.Lock()

    @property
    def inline_limit(self) -> int:
        return self.scheduler.inline_limit

    @inline_limit.setter
    def inline_limit(self, value: int) -> None:
        self.scheduler.inline_limit = value

    @property
    def stats(self) -> dict:
        """Merged component counters (kept flat for existing consumers)."""
        out = {"progress_calls": self._progress_calls}
        out.update(self.scheduler.stats)
        out.update(self.progress.stats)
        return out

    # ------------------------------------------------------------------ setup
    def continue_init(self, info: Optional[Union[dict, ContinueInfo]] = None,
                      **kwargs: Any) -> ContinuationRequest:
        """``MPIX_Continue_init`` analogue.

        The CR's info keys are *defaults*: any individual registration may
        override them with per-registration ``ContinueFlags`` (the
        ``flags=`` argument to ``continue_when``/``continue_all``/the
        combinators), so one CR can aggregate continuations with different
        completion semantics.
        """
        if isinstance(info, ContinueInfo):
            cinfo = info
        else:
            cinfo = make_info(info, **kwargs)
        cr = ContinuationRequest(self, cinfo)
        cr.persistent = True  # CRs are persistent-request-like
        return cr

    # ------------------------------------------------------------ registration
    def continue_when(self, op: Completable, cb, cb_data: Any = None,
                      status: Optional[List[Status]] = None,
                      cr: Optional[ContinuationRequest] = None,
                      flags: Optional[ContinueFlags] = None) -> bool:
        """``MPIX_Continue`` analogue. Returns the immediate-completion flag."""
        return self.continue_all([op], cb, cb_data, statuses=status, cr=cr,
                                 flags=flags)

    def continue_all(self, ops: Sequence[Completable], cb, cb_data: Any = None,
                     statuses: Optional[List[Status]] = None,
                     cr: Optional[ContinuationRequest] = None,
                     flags: Optional[ContinueFlags] = None) -> bool:
        """``MPIX_Continueall`` analogue.

        ``statuses``: None (= MPI_STATUSES_IGNORE) or a caller-allocated list
        of length ``len(ops)`` that is written before the callback runs (or
        before return on immediate completion).

        ``flags``: optional per-registration ``ContinueFlags`` (or mapping)
        overriding the CR's ``ContinueInfo`` defaults for this registration
        only — fast-path participation (``enqueue_complete``), routing
        (``poll_only``), inline eligibility (``immediate`` /
        ``defer_complete``), thread policy, statuses ownership
        (``volatile_statuses``), and error policy (``on_error``).
        """
        if cr is None:
            raise ValueError("a ContinuationRequest is required")
        if statuses is not None and len(statuses) != len(ops):
            raise ValueError("statuses list must match ops length")
        policy = resolve(cr.info, make_flags(flags))
        marked = []
        try:
            for op in ops:
                op.mark_attached()
                marked.append(op)
        except BaseException:
            # Registration failed partway: the already-marked prefix must
            # not stay consumed — the caller still owns those handles.
            for op in marked:
                op.release_attachment()
            raise

        # Immediate-completion fast path (resolved per registration):
        # drive each op's probe once.
        if not policy.enqueue_complete and all(op.done() for op in ops):
            if statuses is not None:
                for i, op in enumerate(ops):
                    statuses[i] = op.status
            cr.stats["immediate"] += 1
            return True

        cont = Continuation(cb, cb_data, ops, statuses, cr, policy)
        cont.seqno = next(self._seq)
        # lifecycle edge 1/4: ops posted with a continuation attached. The
        # sampling decision made here sticks for the continuation's whole
        # lifetime (later edges gate on ``t_posted is not None``).
        tr = _obs.TRACE
        if tr is not None and tr.want(cont.seqno):
            cont.t_posted = ts = tr.now()
            tr.evt(_obs_events.CONT_POSTED, cont.seqno, "core", ts=ts,
                   meta=_obs_events.policy_key(policy))
        try:
            cr._register()           # raises on a freed CR
        except BaseException:
            for op in ops:           # nothing installed yet: full rollback
                op.release_attachment()
            raise
        needs_scan = []
        # Callbacks are never invoked from within continue_[all] itself —
        # registration may happen inside an application critical region
        # (paper §3.1) — so inline execution is suppressed while hooks are
        # installed (a ready continuation lands on the scheduler instead),
        # unless this registration opts in with ``immediate=True``.
        with registration_guard():
            for i, op in enumerate(ops):
                if not op.supports_push and op.state.name == "PENDING":
                    needs_scan.append(op)
                # Hooks fire inline for already-complete ops, so mixed
                # immediate/pending groups resolve correctly.
                op.add_ready_hook(cont.hook_for(i))
        if needs_scan:
            hand_to_waiters = (policy.thread == THREAD_ANY
                               and self.progress.has_waiters)
            for op in needs_scan:
                self.progress.watch(op, use_waiter=hand_to_waiters)
        return False

    # ----------------------------------------------- completion combinators
    def continue_any(self, ops: Sequence[Completable], cb, cb_data: Any = None,
                     statuses: Optional[List[Status]] = None,
                     indices: Optional[List[int]] = None,
                     cr: Optional[ContinuationRequest] = None,
                     flags: Optional[ContinueFlags] = None,
                     cancel_losers: bool = False) -> bool:
        """First-of-n: the callback fires when ANY one op completes
        (``MPI_Testany`` analogue). See ``continue_some`` for the loser
        contract and the ``statuses``/``indices`` reporting."""
        return self.continue_some(ops, 1, cb, cb_data, statuses=statuses,
                                  indices=indices, cr=cr, flags=flags,
                                  cancel_losers=cancel_losers)

    def continue_some(self, ops: Sequence[Completable], k: int, cb,
                      cb_data: Any = None,
                      statuses: Optional[List[Status]] = None,
                      indices: Optional[List[int]] = None,
                      cr: Optional[ContinuationRequest] = None,
                      flags: Optional[ContinueFlags] = None,
                      cancel_losers: bool = False) -> bool:
        """First-k-of-n (``MPI_Testsome``/``Waitsome`` analogue).

        The callback fires once, when the ``k``-th op completes. Reporting
        mirrors ``MPI_Waitsome``: ``indices`` (caller list, any length) is
        rewritten to the winning op indices in completion order, and
        ``statuses`` (caller list of length ``len(ops)``) gets winner
        positions written — both before the callback runs (or before
        return on immediate completion).

        Losers are detached safely: their handles are released (the caller
        may re-attach or drop them), late completions are ignored (the
        callback can never double-fire), and ``cancel_losers=True``
        additionally best-effort-cancels them.
        """
        if cr is None:
            raise ValueError("a ContinuationRequest is required")
        if statuses is not None and len(statuses) != len(ops):
            raise ValueError("statuses list must match ops length")
        comb = when_some(ops, k, cancel_losers=cancel_losers)

        def _report() -> None:
            if indices is not None:
                indices[:] = comb.indices
            if statuses is not None:
                for i in comb.indices:
                    statuses[i] = comb.op_statuses[i]

        def _bridge(_st, data):
            _report()
            cb(statuses, data)

        try:
            flag = self.continue_when(comb, _bridge, cb_data, cr=cr,
                                      flags=flags)
        except BaseException:
            # the composite consumed the children at construction; a failed
            # registration must hand them back, not just the composite —
            # and the orphaned composite must be neutralized so its
            # installed hooks can't later release/cancel attachments owned
            # by a new registration
            comb.detach()
            for op in ops:
                op.release_attachment()
            raise
        if flag:
            _report()
        return flag

    # ------------------------------------------------------ promise front-end
    def wrap(self, op: Completable,
             cr: Optional[ContinuationRequest] = None,
             flags: Optional[ContinueFlags] = None) -> "Promise":
        """Wrap ``op`` into an awaitable/chainable ``Promise``.

        The returned promise resolves with the op's status payload (rejects
        on error/cancellation), supports ``.then()``/``.catch()``
        chaining and ``.cancel()``, and is awaitable from ``async`` code —
        see ``core.promise`` for the asyncio bridge contract. ``cr``
        optionally names the CR to register under (so ``cr.test()`` drives
        poll-mode ops); default is an engine-internal promise CR.
        """
        from repro.core.promise import Promise
        return Promise.of(self, op, cr=cr, flags=flags)

    @property
    def promise_cr(self) -> ContinuationRequest:
        """Engine-internal CR that ``wrap``/Promise registrations default
        to; ``thread=any`` so internal progress/waiter threads may resolve
        promises (resolution is engine-owned code, always safe)."""
        with self._promise_cr_lock:
            if self._promise_cr is None:
                self._promise_cr = self.continue_init(thread=THREAD_ANY)
            return self._promise_cr

    # -------------------------------------------------------------- progress
    def tick(self) -> None:
        """Generic progress: discover + run eligible ready continuations.

        The analogue of "an application thread called into MPI".
        """
        self._progress_calls += 1
        self.progress.scan()
        self.scheduler.drain()

    def enter(self) -> None:
        """Lightweight entry hook: run eligible ready continuations inline.

        Transport (and other substrates) call this on every operation — the
        analogue of "continuations may be invoked as soon as any thread
        calls into MPI" (paper §3) — without paying for a full poll scan.
        """
        self.scheduler.drain(limit=self.scheduler.inline_limit, inline=True)

    def _progress_for_test(self, cr: ContinuationRequest) -> None:
        """Progress driven by ``cr.test()``: bounded by the CR's max_poll.

        Routing is per registration now, so a single CR may hold both
        poll_only continuations (private queue, runnable only here) and
        scheduler-routed ones — drain both under one shared budget.
        """
        self._progress_calls += 1
        self.progress.scan()
        budget = cr.info.max_poll
        ran = self.scheduler.drain_cr_queue(cr, budget)
        remaining = -1 if budget < 0 else max(0, budget - ran)
        # Other CRs' callbacks still run (we are an application thread
        # inside the engine); this CR's scheduler-routed ones are capped
        # by whatever budget the private queue left over.
        self.scheduler.drain(for_cr=cr, cr_limit=remaining)

    # ------------------------------------------------ back-compat delegates
    # Pre-split internal entry points; substrate code now uses the
    # components directly, but external callers may still poke these.
    def _enqueue_ready(self, cont: Continuation) -> None:
        self.scheduler.submit(cont)

    def _drain_ready(self, limit: int = -1, inline: bool = False,
                     for_cr: Optional[ContinuationRequest] = None,
                     cr_limit: int = -1) -> int:
        return self.scheduler.drain(limit=limit, inline=inline,
                                    for_cr=for_cr, cr_limit=cr_limit)

    def _scan_polls(self) -> None:
        self.progress.scan()

    # -------------------------------------------------------------- lifecycle
    def shutdown(self) -> None:
        self.progress.shutdown()

    def register_internal_thread(self) -> None:
        """Mark the calling thread as engine-internal (thread=any gating)."""
        self.scheduler.register_internal_thread()


_default_engine: Optional[Engine] = None
_default_lock = threading.Lock()


def default_engine() -> Engine:
    global _default_engine
    with _default_lock:
        if _default_engine is None:
            _default_engine = Engine()
        return _default_engine


def reset_default_engine() -> None:
    global _default_engine
    with _default_lock:
        if _default_engine is not None:
            _default_engine.shutdown()
        _default_engine = None
