"""The continuation engine — registration, discovery, progress, execution.

Execution model (paper §2–3):

* **Registration** (``continue_when`` / ``continue_all``): attach a callback
  to active op(s); if *all* are already complete and the CR does not set
  ``enqueue_complete``, return ``flag=True`` *without* invoking the callback
  (immediate-completion fast path, paper §2.2). Otherwise the continuation is
  registered with the CR and hooks are installed on each op.

* **Discovery**: push-capable ops (host futures, transport messages, CRs)
  publish completion from whatever thread finished the work — the analogue of
  "any thread calling into MPI" finding the operation complete. Poll-mode ops
  (``jax.Array``) are discovered by progress scans: every engine entry point
  (``tick``, ``cr.test/wait``, transport calls) advances the scan, and an
  optional internal progress thread does too. CRs with ``thread="any"`` may
  additionally hand array ops to *waiter threads* that block on readiness —
  the MPI-internal progress thread analogue.

* **Execution**: a ready continuation runs (a) inline on the discovering
  thread when policy allows (not poll_only; thread policy admits the current
  thread; not nested inside another callback — paper §3.1), else (b) from the
  shared ready queue at the next engine entry of an eligible thread, else
  (c) for poll_only CRs, only inside ``cr.test()`` — bounded by ``max_poll``.
"""
from __future__ import annotations

import collections
import itertools
import queue as queue_mod
import threading
from typing import Any, List, Optional, Sequence, Union

from repro.core.completable import ArrayOp, Completable
from repro.core.continuation import Continuation, ContinuationRequest
from repro.core.info import THREAD_ANY, ContinueInfo, make_info
from repro.core.status import Status

_TLS = threading.local()


def _in_callback() -> bool:
    return getattr(_TLS, "depth", 0) > 0


def _in_registration() -> bool:
    return getattr(_TLS, "registering", 0) > 0


class Engine:
    """A continuations runtime instance. One per process is typical
    (``default_engine()``), but apps may build isolated engines (tests do).
    """

    def __init__(self, *, progress_thread: bool = False,
                 progress_interval: float = 2e-4,
                 n_waiters: int = 0,
                 inline_limit: int = 16,
                 wait_poll_interval: float = 5e-4) -> None:
        # pending poll-mode ops awaiting discovery scans
        self._poll_ops: list[Completable] = []
        self._poll_lock = threading.Lock()
        # ready continuations of non-poll_only CRs
        self._ready: collections.deque[Continuation] = collections.deque()
        self._ready_lock = threading.Lock()
        self._seq = itertools.count()
        #: max continuations drained inline per discovery (bounds latency of
        #: the discovering thread; the full queue drains on test/tick)
        self.inline_limit = inline_limit
        self.wait_poll_interval = wait_poll_interval
        self._internal_threads: set[int] = set()
        self._shutdown = threading.Event()
        self._progress_thread: Optional[threading.Thread] = None
        if progress_thread:
            self._progress_thread = threading.Thread(
                target=self._progress_loop, args=(progress_interval,),
                name="contin-progress", daemon=True)
            self._progress_thread.start()
        self._waiter_q: "queue_mod.Queue[Optional[ArrayOp]]" = queue_mod.Queue()
        self._waiters = [
            threading.Thread(target=self._waiter_loop,
                             name=f"contin-waiter-{i}", daemon=True)
            for i in range(n_waiters)]
        for w in self._waiters:
            w.start()
        self.stats = {"progress_calls": 0, "inline_runs": 0, "queued_runs": 0,
                      "poll_scans": 0}

    # ------------------------------------------------------------------ setup
    def continue_init(self, info: Optional[Union[dict, ContinueInfo]] = None,
                      **kwargs: Any) -> ContinuationRequest:
        """``MPIX_Continue_init`` analogue."""
        if isinstance(info, ContinueInfo):
            cinfo = info
        else:
            cinfo = make_info(info, **kwargs)
        cr = ContinuationRequest(self, cinfo)
        cr.persistent = True  # CRs are persistent-request-like
        return cr

    # ------------------------------------------------------------ registration
    def continue_when(self, op: Completable, cb, cb_data: Any = None,
                      status: Optional[List[Status]] = None,
                      cr: Optional[ContinuationRequest] = None) -> bool:
        """``MPIX_Continue`` analogue. Returns the immediate-completion flag."""
        return self.continue_all([op], cb, cb_data, statuses=status, cr=cr)

    def continue_all(self, ops: Sequence[Completable], cb, cb_data: Any = None,
                     statuses: Optional[List[Status]] = None,
                     cr: Optional[ContinuationRequest] = None) -> bool:
        """``MPIX_Continueall`` analogue.

        ``statuses``: None (= MPI_STATUSES_IGNORE) or a caller-allocated list
        of length ``len(ops)`` that is written before the callback runs (or
        before return on immediate completion).
        """
        if cr is None:
            raise ValueError("a ContinuationRequest is required")
        if statuses is not None and len(statuses) != len(ops):
            raise ValueError("statuses list must match ops length")
        for op in ops:
            op.mark_attached()

        # Immediate-completion fast path: drive each op's probe once.
        if not cr.info.enqueue_complete and all(op.done() for op in ops):
            if statuses is not None:
                for i, op in enumerate(ops):
                    statuses[i] = op.status
            cr.stats["immediate"] += 1
            return True

        cont = Continuation(cb, cb_data, ops, statuses, cr)
        cont.seqno = next(self._seq)
        cr._register()
        needs_scan = []
        # Callbacks are never invoked from within continue_[all] itself —
        # registration may happen inside an application critical region
        # (paper §3.1) — so inline execution is suppressed while hooks are
        # installed; a ready continuation lands on the queue instead.
        _TLS.registering = getattr(_TLS, "registering", 0) + 1
        try:
            for i, op in enumerate(ops):
                if not op.supports_push and op.state.name == "PENDING":
                    needs_scan.append(op)
                # Hooks fire inline for already-complete ops, so mixed
                # immediate/pending groups resolve correctly.
                op.add_ready_hook(cont.hook_for(i))
        finally:
            _TLS.registering -= 1
        if needs_scan:
            hand_to_waiters = (cr.info.thread == THREAD_ANY and self._waiters)
            with self._poll_lock:
                for op in needs_scan:
                    if hand_to_waiters and isinstance(op, ArrayOp):
                        self._waiter_q.put(op)
                    else:
                        self._poll_ops.append(op)
        return False

    # ------------------------------------------------------------- discovery
    def _enqueue_ready(self, cont: Continuation) -> None:
        """A continuation of a non-poll_only CR became ready."""
        with self._ready_lock:
            self._ready.append(cont)
        if _in_registration():
            return  # never execute inside continue_[all] (paper §3.1)
        # Low-latency path: run inline if the current thread is eligible.
        self._drain_ready(limit=self.inline_limit, inline=True)

    def _thread_eligible(self, cr: ContinuationRequest) -> bool:
        if _in_callback():
            return False  # no nested continuation execution (paper §3.1)
        if threading.get_ident() in self._internal_threads:
            return cr.info.thread == THREAD_ANY
        return True

    def _scan_polls(self) -> None:
        """Discover completions of poll-mode ops (cheap, lock-sliced)."""
        self.stats["poll_scans"] += 1
        with self._poll_lock:
            ops = list(self._poll_ops)
        done_ops = [op for op in ops if op.done()]  # done() fires hooks
        if done_ops:
            done_set = set(map(id, done_ops))
            with self._poll_lock:
                self._poll_ops = [op for op in self._poll_ops
                                  if id(op) not in done_set]

    # ------------------------------------------------------------- execution
    def _run_one(self, cont: Continuation) -> None:
        _TLS.depth = getattr(_TLS, "depth", 0) + 1
        try:
            err = cont.run()
        finally:
            _TLS.depth -= 1
        cont.cr._deregister(err)

    def _drain_ready(self, limit: int = -1, inline: bool = False,
                     for_cr: Optional[ContinuationRequest] = None,
                     cr_limit: int = -1) -> int:
        """Run ready continuations from the shared queue.

        ``cr_limit`` caps executions belonging to ``for_cr`` (max_poll during
        a test of that CR). Ineligible continuations (thread policy) are
        requeued for an eligible thread.
        """
        ran = 0
        ran_for_cr = 0
        requeue: list[Continuation] = []
        while limit < 0 or ran < limit:
            with self._ready_lock:
                if not self._ready:
                    break
                cont = self._ready.popleft()
            if not self._thread_eligible(cont.cr):
                requeue.append(cont)
                # inline discovery on an ineligible thread: stop early
                if inline:
                    break
                continue
            if for_cr is not None and cont.cr is for_cr and cr_limit >= 0 \
                    and ran_for_cr >= cr_limit:
                requeue.append(cont)
                break
            self._run_one(cont)
            ran += 1
            if for_cr is not None and cont.cr is for_cr:
                ran_for_cr += 1
            self.stats["inline_runs" if inline else "queued_runs"] += 1
        if requeue:
            with self._ready_lock:
                self._ready.extendleft(reversed(requeue))
        return ran

    def _drain_cr_queue(self, cr: ContinuationRequest, limit: int) -> int:
        """Run a poll_only CR's private ready queue (inside cr.test())."""
        ran = 0
        while limit < 0 or ran < limit:
            with cr._lock:
                if not cr._ready_q:
                    break
                cont = cr._ready_q.popleft()
            self._run_one(cont)
            ran += 1
        return ran

    # -------------------------------------------------------------- progress
    def tick(self) -> None:
        """Generic progress: discover + run eligible ready continuations.

        The analogue of "an application thread called into MPI".
        """
        self.stats["progress_calls"] += 1
        self._scan_polls()
        self._drain_ready()

    def _progress_for_test(self, cr: ContinuationRequest) -> None:
        """Progress driven by ``cr.test()``: bounded by the CR's max_poll."""
        self.stats["progress_calls"] += 1
        self._scan_polls()
        budget = cr.info.max_poll
        if cr.info.poll_only:
            # Other CRs' callbacks still run (we are an application thread
            # inside the engine) — but this CR's run only here, capped.
            self._drain_cr_queue(cr, budget)
            self._drain_ready()
        else:
            self._drain_ready(for_cr=cr, cr_limit=budget)

    def _progress_loop(self, interval: float) -> None:
        self._internal_threads.add(threading.get_ident())
        while not self._shutdown.wait(interval):
            self._scan_polls()
            self._drain_ready()

    def _waiter_loop(self) -> None:
        self._internal_threads.add(threading.get_ident())
        while True:
            op = self._waiter_q.get()
            if op is None or self._shutdown.is_set():
                break
            op.block()           # fires hooks on this internal thread
            self._drain_ready()  # eligible only for thread=any CRs

    # -------------------------------------------------------------- lifecycle
    def shutdown(self) -> None:
        self._shutdown.set()
        for _ in self._waiters:
            self._waiter_q.put(None)
        for w in self._waiters:
            w.join(timeout=2.0)
        if self._progress_thread is not None:
            self._progress_thread.join(timeout=2.0)

    def register_internal_thread(self) -> None:
        """Mark the calling thread as engine-internal (thread=any gating)."""
        self._internal_threads.add(threading.get_ident())


_default_engine: Optional[Engine] = None
_default_lock = threading.Lock()


def default_engine() -> Engine:
    global _default_engine
    with _default_lock:
        if _default_engine is None:
            _default_engine = Engine()
        return _default_engine


def reset_default_engine() -> None:
    global _default_engine
    with _default_lock:
        if _default_engine is not None:
            _default_engine.shutdown()
        _default_engine = None
