"""Pluggable continuation schedulers — execution/eligibility/ready-queue.

This is the *execution* third of the engine split (paper §3.1, plus the
fibers-vs-pthreads observation that execution policy should be decoupled
from the completion interface):

    Progress  (core.progress)   — *discovers* completions,
    Scheduler (this module)     — decides *where/when* ready continuations
                                  execute and runs them,
    Engine    (core.engine)     — thin facade wiring the two plus the
                                  info-key policy and the registration API.

A ``Scheduler`` owns the ready queue(s) of non-``poll_only`` continuations
and the thread-eligibility policy:

* no nested execution — a callback never runs inside another callback
  (paper §3.1),
* no execution inside ``continue_when``/``continue_all`` — registration may
  happen inside an application critical region (paper §3.1),
* engine-internal threads (progress thread, waiters, transport delivery)
  run only continuations of ``thread="any"`` CRs (paper §3.5).

Two implementations:

* ``FifoScheduler``     — one shared queue + one lock; global FIFO order.
  Simple and fair, but every ``submit``/``drain`` on the hot path takes the
  same lock from every thread.
* ``AffinityScheduler`` — per-thread local queues plus a shared overflow
  queue with work stealing. A completion discovered on thread *T* lands on
  *T*'s local queue (usually drained inline by *T* a few instructions
  later) without touching any shared lock; ineligible or stolen work
  migrates through the shared queue, so nothing strands on a thread that
  never re-enters the engine.

Every queue is a ``core.continuation.ClassDeque``: registrations with the
per-registration ``priority`` flag > 0 drain ahead of normal work but
stay FIFO within their priority class.

Select per engine: ``Engine(scheduler="fifo"|"affinity")`` or pass a
``Scheduler`` instance.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

from repro.core.continuation import (ClassDeque, Continuation,
                                     ContinuationRequest)
from repro.core.info import THREAD_ANY
from repro.obs import tracer as _obs

_TLS = threading.local()


def in_callback() -> bool:
    """True while the current thread is executing a continuation body."""
    return getattr(_TLS, "depth", 0) > 0


def in_registration() -> bool:
    """True while the current thread is inside continue_when/continue_all."""
    return getattr(_TLS, "registering", 0) > 0


class registration_guard:
    """Suppress inline execution while hooks are installed (paper §3.1)."""

    def __enter__(self):
        _TLS.registering = getattr(_TLS, "registering", 0) + 1
        return self

    def __exit__(self, *exc):
        _TLS.registering -= 1
        return False


class Scheduler:
    """Base class: the drain/eligibility machinery over queue primitives.

    Subclasses supply ``_push`` / ``_pop`` / ``_requeue`` (and may override
    ``pending`` for introspection).
    """

    name = "base"

    def __init__(self, *, inline_limit: int = 16) -> None:
        #: max continuations drained inline per discovery (bounds latency of
        #: the discovering thread; the full queue drains on test/tick)
        self.inline_limit = inline_limit
        self._internal_threads: set[int] = set()
        self.stats = {"inline_runs": 0, "queued_runs": 0}

    # ------------------------------------------------------ queue primitives
    def _push(self, cont: Continuation) -> None:
        raise NotImplementedError

    def _pop(self) -> Optional[Continuation]:
        raise NotImplementedError

    def _requeue(self, conts: Sequence[Continuation]) -> None:
        raise NotImplementedError

    @property
    def pending(self) -> int:
        raise NotImplementedError

    # ------------------------------------------------------- thread policy
    def register_internal_thread(self) -> None:
        """Mark the calling thread as engine-internal (thread=any gating)."""
        self._internal_threads.add(threading.get_ident())

    def thread_eligible(self, cr: ContinuationRequest) -> bool:
        """CR-level eligibility (pre-flags compat; prefer ``eligible``)."""
        if in_callback():
            return False  # no nested continuation execution (paper §3.1)
        if threading.get_ident() in self._internal_threads:
            return cr.info.thread == THREAD_ANY
        return True

    def eligible(self, cont: Continuation, inline: bool) -> bool:
        """May the *current thread* execute this continuation *now*?

        Resolved per registration (``cont.policy``): ``thread`` gates
        engine-internal threads; ``immediate`` opts out of the
        registration guard; ``defer_complete`` vetoes the inline
        discovery path entirely.
        """
        if in_callback():
            return False  # no nested continuation execution (paper §3.1)
        if in_registration() and not cont.policy.immediate:
            return False  # inside continue_[all] (paper §3.1)
        if inline and cont.policy.defer_complete:
            return False  # must wait for a drain from an entry point
        if threading.get_ident() in self._internal_threads:
            return cont.policy.thread == THREAD_ANY
        return True

    # ----------------------------------------------------------- execution
    def submit(self, cont: Continuation) -> None:
        """A continuation of a non-poll_only registration became ready."""
        self._push(cont)
        if in_registration() and not cont.policy.immediate:
            return  # never execute inside continue_[all] (paper §3.1)
        # Low-latency path: run inline if the current thread is eligible.
        self.drain(limit=self.inline_limit, inline=True)

    def run_one(self, cont: Continuation) -> None:
        # lifecycle edge 4/4: callback execution. Stamped only for
        # continuations sampled at registration; the span + all four
        # inter-edge histograms are emitted by ``lifecycle_ran``.
        tr = _obs.TRACE
        t_run = (tr.now() if tr is not None and cont.t_posted is not None
                 else None)
        _TLS.depth = getattr(_TLS, "depth", 0) + 1
        try:
            err = cont.run()
        finally:
            _TLS.depth -= 1
        if t_run is not None:
            tr.lifecycle_ran(cont, t_run)
        cont.cr._deregister(err, cont.policy)

    def drain(self, limit: int = -1, inline: bool = False,
              for_cr: Optional[ContinuationRequest] = None,
              cr_limit: int = -1) -> int:
        """Run ready continuations from the queue(s).

        ``cr_limit`` caps executions belonging to ``for_cr`` (max_poll during
        a test of that CR). Ineligible continuations (thread policy) are
        requeued for an eligible thread.
        """
        ran = 0
        ran_for_cr = 0
        requeue: List[Continuation] = []
        while limit < 0 or ran < limit:
            cont = self._pop()
            if cont is None:
                break
            if not self.eligible(cont, inline):
                requeue.append(cont)
                # inline discovery on an ineligible thread: stop early
                if inline:
                    break
                continue
            if for_cr is not None and cont.cr is for_cr and cr_limit >= 0 \
                    and ran_for_cr >= cr_limit:
                # over budget for the tested CR: park it, but keep going —
                # other CRs' ready continuations behind it must still run
                # (each queue item is popped at most once per drain; the
                # requeue list is only flushed on exit, so no livelock)
                requeue.append(cont)
                continue
            self.run_one(cont)
            ran += 1
            if for_cr is not None and cont.cr is for_cr:
                ran_for_cr += 1
            self.stats["inline_runs" if inline else "queued_runs"] += 1
        if requeue:
            self._requeue(requeue)
        return ran

    def drain_cr_queue(self, cr: ContinuationRequest, limit: int) -> int:
        """Run a poll_only CR's private ready queue (inside cr.test())."""
        ran = 0
        while limit < 0 or ran < limit:
            with cr._lock:
                cont = cr._ready_q.pop()
            if cont is None:
                break
            self.run_one(cont)
            ran += 1
        return ran


class FifoScheduler(Scheduler):
    """The reference policy: one shared lock, one ``ClassDeque`` —
    global FIFO within each priority class (priority>0 drains first; see
    ``ClassDeque`` for why jumping must not reorder a class)."""

    name = "fifo"

    def __init__(self, *, inline_limit: int = 16) -> None:
        super().__init__(inline_limit=inline_limit)
        self._ready = ClassDeque()
        self._lock = threading.Lock()

    def _push(self, cont: Continuation) -> None:
        with self._lock:
            self._ready.push(cont)

    def _pop(self) -> Optional[Continuation]:
        with self._lock:
            return self._ready.pop()

    def _requeue(self, conts: Sequence[Continuation]) -> None:
        with self._lock:
            for cont in reversed(conts):
                self._ready.push_front(cont)

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._ready)


class _LocalQueue:
    __slots__ = ("lock", "q")

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.q = ClassDeque()


class AffinityScheduler(Scheduler):
    """Per-thread affinity queues with work stealing.

    The hot ``submit``→inline-``drain`` path (a completion discovered and
    executed on the same thread, the common case by far) touches only the
    discovering thread's own queue — no shared-lock contention. Ineligible
    continuations migrate to the shared overflow deque; drains on any
    thread fall back to the shared deque and then *steal* from other
    threads' local queues, so no continuation can strand on a thread that
    never calls into the engine again.
    """

    name = "affinity"

    def __init__(self, *, inline_limit: int = 16) -> None:
        super().__init__(inline_limit=inline_limit)
        self._locals: Dict[int, _LocalQueue] = {}
        self._locals_lock = threading.Lock()
        self._shared = ClassDeque()      # overflow (class-FIFO, like all)
        self._shared_lock = threading.Lock()
        self.stats["local_pushes"] = 0
        self.stats["shared_pushes"] = 0
        self.stats["steals"] = 0

    def _my_queue(self) -> _LocalQueue:
        tid = threading.get_ident()
        lq = self._locals.get(tid)
        if lq is None:
            with self._locals_lock:
                lq = self._locals.setdefault(tid, _LocalQueue())
        return lq

    def _push(self, cont: Continuation) -> None:
        # Internal threads park work on the shared deque: their local queue
        # would only ever be drained under the thread=any policy.
        if threading.get_ident() in self._internal_threads:
            with self._shared_lock:
                self._shared.push(cont)
            self.stats["shared_pushes"] += 1
            return
        lq = self._my_queue()
        with lq.lock:
            lq.q.push(cont)
        self.stats["local_pushes"] += 1

    def _pop(self) -> Optional[Continuation]:
        # 1. own local queue (cache-hot, uncontended in the common case)
        lq = self._locals.get(threading.get_ident())
        if lq is not None:
            with lq.lock:
                cont = lq.q.pop()
            if cont is not None:
                return cont
        # 2. shared overflow
        with self._shared_lock:
            cont = self._shared.pop()
        if cont is not None:
            return cont
        # 3. steal from another thread's local queue
        with self._locals_lock:
            victims = list(self._locals.values())
        for victim in victims:
            if victim is lq:
                continue
            with victim.lock:
                cont = victim.q.pop()
            if cont is not None:
                self.stats["steals"] += 1
                return cont
        return None

    def _requeue(self, conts: Sequence[Continuation]) -> None:
        # Requeued work was ineligible on this thread — publish it where any
        # other thread will find it first.
        with self._shared_lock:
            for cont in reversed(conts):
                self._shared.push_front(cont)

    @property
    def pending(self) -> int:
        with self._shared_lock:
            n = len(self._shared)
        with self._locals_lock:
            victims = list(self._locals.values())
        for lq in victims:
            with lq.lock:
                n += len(lq.q)
        return n


_SCHEDULERS = {
    FifoScheduler.name: FifoScheduler,
    AffinityScheduler.name: AffinityScheduler,
}


def make_scheduler(spec, *, inline_limit: int = 16) -> Scheduler:
    """Resolve a scheduler spec: instance, class, or registered name."""
    if isinstance(spec, Scheduler):
        return spec
    if isinstance(spec, type) and issubclass(spec, Scheduler):
        return spec(inline_limit=inline_limit)
    try:
        cls = _SCHEDULERS[spec]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {spec!r}; known: {sorted(_SCHEDULERS)}"
        ) from None
    return cls(inline_limit=inline_limit)
