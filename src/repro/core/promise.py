"""Awaitable promise front-end over the continuation engine.

The raw engine surface is ``cb(statuses, cb_data)`` — exactly right for
runtime-internal completion plumbing, and exactly wrong for slotting under
higher-level asynchronous programming models (the fibers-vs-pthreads
companion paper's point: continuations should *compose into* whatever APM
the application uses). ``Promise`` is that bridge:

* ``engine.wrap(op)`` returns a ``Promise`` that resolves with the op's
  status payload (rejects on error; rejects ``PromiseCancelled`` on
  cancellation).
* ``.then(fn)`` / ``.catch(fn)`` chain: handlers run when the promise
  settles (immediately if already settled, on the settling thread
  otherwise); a handler returning a ``Promise`` or a ``Completable`` is
  adopted, so continuation pipelines read top-to-bottom.
* ``.cancel()`` propagates to the underlying operation; the rejection then
  flows through the same resolution path as any other completion.
* ``await promise`` works from any running asyncio event loop. Wakeups are
  loop-safe: a resolution arriving from a foreign thread is delivered via
  ``loop.call_soon_threadsafe``; a resolution on the loop thread itself
  sets the future directly (no extra loop hop — the awaitable-bridge
  latency the ``core.api.*`` bench gates). While an awaited promise is
  unsettled the bridge keeps the engine progressing from the loop
  (``call_later`` ticks), so poll-mode ops (``ArrayOp``, ``TimerOp``)
  resolve without any thread ever blocking in the engine.

Resolution itself is engine-owned code (record value, wake waiters, run
chained handlers), registered with per-registration flags
``enqueue_complete=True`` (an already-complete op still resolves through
the machinery) and ``immediate=True`` (safe to run inline even inside
``continue_when``).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional, Sequence

from repro.core.completable import Completable, when_all, when_any
from repro.core.flags import ContinueFlags, merge_flags
from repro.core.status import Status

PENDING = "pending"
FULFILLED = "fulfilled"
REJECTED = "rejected"

#: flags every promise-resolution registration uses (see module docstring)
_RESOLVE_FLAGS = ContinueFlags(enqueue_complete=True, immediate=True)

# Per-thread cache of the running event loop: ``asyncio.get_running_loop``
# is a surprisingly expensive call on some sandboxed kernels (~20us), and
# the await bridge needs the loop on every ``__await__``. A cached loop is
# valid while it is still running on this thread (two loops cannot run on
# one thread, and a finished ``asyncio.run`` leaves ``is_running`` False).
_BRIDGE_TLS = threading.local()


def _running_loop():
    loop = getattr(_BRIDGE_TLS, "loop", None)
    if loop is None or not loop.is_running():
        import asyncio
        loop = asyncio.get_running_loop()
        _BRIDGE_TLS.loop = loop
    return loop


class PromiseCancelled(Exception):
    """The promise's underlying operation was cancelled."""


class Promise:
    """A one-shot settled-exactly-once value with chaining and await."""

    def __init__(self, engine=None, op: Optional[Completable] = None) -> None:
        self._engine = engine
        self._op = op                  # cancellation target (may be None)
        self._lock = threading.Lock()
        self._state = PENDING
        self._value: Any = None        # fulfil value or rejection error
        self._settle_cbs: List[Callable[[str, Any], None]] = []
        # blocking waiters are rare (await/then don't block): the
        # condition is created lazily by result() — an Event here would
        # put a kernel wakeup on every settle
        self._waiter: Optional[threading.Condition] = None

    # ------------------------------------------------------------ construction
    @classmethod
    def of(cls, engine, op: Completable, cr=None,
           flags: Optional[ContinueFlags] = None) -> "Promise":
        """Promise over one operation (``engine.wrap`` calls this).

        ``flags`` layers extra per-registration flags (e.g. ``thread``)
        over the promise-resolution defaults.
        """
        p = cls(engine, op)
        use_cr = cr if cr is not None else engine.promise_cr

        def _resolve(_statuses, _data, _p=p, _op=op, _settle=p._settle):
            st = _op._status
            if st.error is not None:
                _settle(REJECTED, st.error)
            elif st.cancelled:
                _settle(REJECTED, PromiseCancelled())
            else:
                _settle(FULFILLED, st.payload)

        engine.continue_when(op, _resolve, cr=use_cr,
                             flags=merge_flags(_RESOLVE_FLAGS, flags))
        return p

    @classmethod
    def all_of(cls, engine, ops: Sequence[Completable], cr=None) -> "Promise":
        """Promise over ``when_all(ops)`` — fulfils with the payload list."""
        return cls.of(engine, when_all(ops), cr=cr)

    @classmethod
    def any_of(cls, engine, ops: Sequence[Completable], *, cr=None,
               cancel_losers: bool = False) -> "Promise":
        """Promise over ``when_any(ops)`` — fulfils with the winner payload."""
        return cls.of(engine, when_any(ops, cancel_losers=cancel_losers),
                      cr=cr)

    @classmethod
    def deferred(cls, engine=None) -> "Promise":
        """Externally-settled promise: call ``.resolve()``/``.reject()``."""
        return cls(engine, None)

    # ------------------------------------------------------------- settling
    def _settle(self, state: str, value: Any) -> bool:
        lock = self._lock
        lock.acquire()
        if self._state is not PENDING:
            lock.release()
            return False
        self._value = value
        self._state = state              # written last: lock-free readers
        cbs = self._settle_cbs
        self._settle_cbs = ()
        if self._waiter is not None:
            self._waiter.notify_all()
        lock.release()
        for cb in cbs:
            try:
                cb(state, value)
            except Exception:
                # settle callbacks are delivery plumbing (asyncio futures,
                # then-children): one broken consumer (e.g. a closed event
                # loop) must not starve the others or blow up the engine
                # thread that settled the promise
                pass
        return True

    def _fulfill(self, value: Any) -> bool:
        return self._settle(FULFILLED, value)

    def _reject(self, error: BaseException) -> bool:
        return self._settle(REJECTED, error)

    # public aliases for deferred promises (external producers)
    resolve = _fulfill
    reject = _reject

    def _on_settle(self, cb: Callable[[str, Any], None]) -> None:
        """Run ``cb(state, value)`` at settle; immediately if settled."""
        with self._lock:
            if self._state is PENDING:
                self._settle_cbs.append(cb)
                return
            state, value = self._state, self._value
        cb(state, value)

    # ------------------------------------------------------------ inspection
    @property
    def state(self) -> str:
        return self._state

    def done(self) -> bool:
        return self._state is not PENDING

    # -------------------------------------------------------------- chaining
    def then(self, on_fulfilled: Optional[Callable[[Any], Any]] = None,
             on_rejected: Optional[Callable[[BaseException], Any]] = None
             ) -> "Promise":
        """Chain: returns a promise settled from the handler's outcome.

        The matching handler runs on the settling thread (immediately, if
        this promise already settled). A handler returning a ``Promise``
        or ``Completable`` is adopted; a raise rejects the child. A
        missing handler passes fulfilment/rejection through unchanged.
        """
        child = Promise(self._engine, self._op)  # cancel() reaches the source

        def _settle(state: str, value: Any) -> None:
            handler = on_fulfilled if state is FULFILLED else on_rejected
            if handler is None:
                if state is FULFILLED:
                    child._fulfill(value)
                else:
                    child._reject(value)
                return
            try:
                out = handler(value)
            except BaseException as exc:
                child._reject(exc)
                return
            child._adopt(out)

        self._on_settle(_settle)
        return child

    def catch(self, on_rejected: Callable[[BaseException], Any]) -> "Promise":
        return self.then(None, on_rejected)

    def _adopt(self, out: Any) -> None:
        """Settle from a handler result (promise/op chaining)."""
        if isinstance(out, Promise):
            self._op = out._op if out._op is not None else self._op
            out._on_settle(
                lambda s, v: self._fulfill(v) if s is FULFILLED
                else self._reject(v))
        elif isinstance(out, Completable) and self._engine is not None:
            self._adopt(Promise.of(self._engine, out))
        else:
            self._fulfill(out)

    # ---------------------------------------------------------- cancellation
    def cancel(self) -> bool:
        """Best-effort cancel of the underlying operation.

        The rejection (``PromiseCancelled``) arrives through the normal
        resolution path, so chained children settle consistently. A
        deferred promise (no underlying op) rejects directly.
        """
        if self._op is not None:
            return self._op.cancel()
        return self._reject(PromiseCancelled())

    # ------------------------------------------------------------- sync wait
    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until settled, driving engine progress; return the value
        or raise the rejection error. Not for use inside callbacks."""
        import time
        deadline = None if timeout is None else time.monotonic() + timeout
        interval = (self._engine.wait_poll_interval
                    if self._engine is not None else 5e-4)
        while self._state is PENDING:
            if self._engine is not None:
                self._engine.tick()
            if deadline is not None and time.monotonic() >= deadline:
                if self._state is PENDING:
                    raise TimeoutError("promise unsettled after timeout")
                break
            with self._lock:
                if self._state is not PENDING:
                    break
                if self._waiter is None:
                    self._waiter = threading.Condition(self._lock)
                self._waiter.wait(interval)
        if self._state is REJECTED:
            raise self._value
        return self._value

    # ---------------------------------------------------------- asyncio bridge
    def __await__(self):
        if self._state is not PENDING:       # settled: no future, no loop
            if self._state is REJECTED:
                raise self._value
            return _settled_iter(self._value)
        loop = _running_loop()
        fut = loop.create_future()
        loop_thread = threading.get_ident()

        def _deliver(state: str, value: Any) -> None:
            def _set() -> None:
                if fut.cancelled():
                    return
                if state is FULFILLED:
                    fut.set_result(value)
                else:
                    fut.set_exception(value)

            if threading.get_ident() == loop_thread:
                _set()                       # loop thread: no extra hop
            else:
                loop.call_soon_threadsafe(_set)

        self._on_settle(_deliver)
        self._schedule_progress(loop)
        return fut.__await__()

    def _schedule_progress(self, loop) -> None:
        """Keep the engine progressing from the loop while unsettled, so
        poll-mode ops resolve without a dedicated progress thread.

        One driver chain per (engine, loop) — N concurrent awaits share a
        single ``call_later`` tick chain instead of scheduling N redundant
        full progress scans per interval. The registry is thread-local
        (the loop is bound to this thread); the chain retires itself when
        its watch set drains, and a stale entry from a finished loop is
        simply replaced.
        """
        eng = self._engine
        if eng is None or self._state is not PENDING:
            return
        drivers = getattr(_BRIDGE_TLS, "drivers", None)
        if drivers is None:
            drivers = _BRIDGE_TLS.drivers = {}
        # Purge entries from loops that are no longer running on this
        # thread (a chain's final retirement tick is often scheduled after
        # asyncio.run() already closed the loop): only one loop runs per
        # thread, so anything not running is dead — without this the dict
        # pins finished loops/engines for the thread's lifetime and id()
        # reuse could alias a dead entry to a new engine.
        for stale in [k for k, (lp, _w) in drivers.items()
                      if lp is not loop and not lp.is_running()]:
            del drivers[stale]
        key = id(eng)
        entry = drivers.get(key)
        if entry is not None and entry[0] is loop:
            entry[1].add(self)           # driver already running: join it
            return
        watch = {self}
        drivers[key] = (loop, watch)
        interval = max(eng.wait_poll_interval, 1e-4)

        def _poll() -> None:
            live = [p for p in watch if p._state is PENDING]
            watch.clear()
            watch.update(live)
            if not live:
                if drivers.get(key) is not None \
                        and drivers[key][0] is loop:
                    del drivers[key]
                return
            eng.tick()
            loop.call_later(interval, _poll)

        loop.call_soon(_poll)


def _settled_iter(value):
    """Iterator for awaiting an already-settled promise: returns the value
    to ``yield from`` without ever yielding to the event loop."""
    return value
    yield  # pragma: no cover — generator marker


class Signal:
    """Multi-shot settle support: a re-armable completion signal.

    A ``Promise`` settles exactly once — right for one operation, wrong
    for a *stream* of completions (per-token delivery, repeated sweeps).
    ``Signal`` chains one-shot promises into a multi-shot gate:

    * ``wait()`` returns the **current generation's** promise. Await it
      (loop-safe, same asyncio bridge as any promise) or chain on it.
    * ``set(value)`` fulfils the current generation and atomically arms a
      fresh one, so the next ``wait()`` observes only *later* sets.

    The lost-wakeup-free consumer pattern is **arm → check → await**::

        while True:
            p = signal.wait()          # arm FIRST
            if <state check finds work or a terminal condition>:
                ...consume/return...   # p is simply dropped
                continue
            await p                    # fulfilled by any set() after wait()

    Any ``set()`` that raced between the arm and the check fulfilled the
    armed promise, so the await cannot sleep through it. A ``set()``
    with **no armed waiter is a cheap no-op** (no promise churn on the
    producer's hot path — a decode loop signalling per token pays only a
    flag check while nobody streams asynchronously); consequently the
    signal is a *wakeup* gate, not a value channel — consumers must read
    the actual state in the check step, exactly as the pattern above
    does. Producers call ``set()`` *after* publishing state; ``set()``
    never blocks, so a completion continuation can signal safely.
    """

    def __init__(self, engine=None) -> None:
        self._engine = engine
        self._lock = threading.Lock()
        self._current = Promise(engine, None)
        self._armed = False
        self.fired = 0        # total set() calls (informational)

    def wait(self) -> Promise:
        """Arm: the promise fulfilled by the next ``set()``."""
        with self._lock:
            self._armed = True
            return self._current

    def set(self, value: Any = None) -> None:
        """Fulfil the armed generation (if any) and re-arm a fresh one."""
        with self._lock:
            self.fired += 1
            if not self._armed:
                return                 # nobody waiting: skip the churn
            self._armed = False
            settled, self._current = self._current, Promise(self._engine,
                                                            None)
        settled._fulfill(value)


def wrap(engine, op: Completable, cr=None) -> Promise:
    """Module-level alias of ``engine.wrap``."""
    return Promise.of(engine, op, cr=cr)
