"""Info-key controls for continuation requests (paper §3.5).

These are now the CR-level *defaults*: any individual registration may
override them with per-registration ``ContinueFlags`` (``core.flags``,
the ``flags=`` argument of ``continue_when``/``continue_all``/the
combinators). The MPI-style ``mpi_continue_*`` string keys accepted by
``make_info`` are deprecated in favour of field-name kwargs, but keep
working — existing call sites migrate at their own pace.

Five keys, mirrored 1:1 from the paper:

* ``poll_only``          — callbacks run only inside an explicit completion
                           call (``cr.test()`` / ``cr.wait()``) on *this* CR.
* ``enqueue_complete``   — ``continue_when/all`` never reports immediate
                           completion; already-complete ops are enqueued.
* ``max_poll``           — cap on callbacks executed per test of this CR
                           (-1 = unlimited).
* ``thread``             — "application": callbacks only on threads that call
                           into the engine; "any": engine-internal progress /
                           waiter threads may run them.
* ``async_signal_safe``  — hint retained from the paper; in this Python
                           runtime it additionally permits execution on timer
                           threads (documented adaptation, DESIGN.md §2).

``poll_only=True`` with ``max_poll=0`` is erroneous (paper: no continuation
registered with such a CR could ever run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional

THREAD_APPLICATION = "application"
THREAD_ANY = "any"


@dataclasses.dataclass(frozen=True)
class ContinueInfo:
    poll_only: bool = False
    enqueue_complete: bool = False
    max_poll: int = -1
    thread: str = THREAD_APPLICATION
    async_signal_safe: bool = False
    #: beyond-paper framework key: how callback exceptions surface
    #: ("raise" = re-raised from the next test/wait; "collect" = stored)
    on_error: str = "raise"

    def __post_init__(self) -> None:
        if self.thread not in (THREAD_APPLICATION, THREAD_ANY):
            raise ValueError(f"mpi_continue_thread must be 'application' or "
                             f"'any', got {self.thread!r}")
        if self.max_poll < -1:
            raise ValueError("mpi_continue_max_poll must be >= -1")
        if self.poll_only and self.max_poll == 0:
            raise ValueError(
                "mpi_continue_poll_only=true with mpi_continue_max_poll=0 is "
                "erroneous: no continuation could ever be executed (paper §3.5)")
        if self.on_error not in ("raise", "collect"):
            raise ValueError("on_error must be 'raise' or 'collect'")


_KEYMAP = {
    "mpi_continue_poll_only": "poll_only",
    "mpi_continue_enqueue_complete": "enqueue_complete",
    "mpi_continue_max_poll": "max_poll",
    "mpi_continue_thread": "thread",
    "mpi_continue_async_signal_safe": "async_signal_safe",
    "on_error": "on_error",
}


def _coerce(field: str, value: Any) -> Any:
    if field in ("poll_only", "enqueue_complete", "async_signal_safe"):
        if isinstance(value, str):
            return value.lower() in ("true", "1", "yes")
        return bool(value)
    if field == "max_poll":
        return int(value)
    return value


def make_info(info: Optional[Mapping[str, Any]] = None, /, **kwargs: Any) -> ContinueInfo:
    """Build a ``ContinueInfo`` from MPI-style string keys and/or kwargs."""
    fields: dict[str, Any] = {}
    for key, value in (info or {}).items():
        field = _KEYMAP.get(key, key)
        if field not in ContinueInfo.__dataclass_fields__:
            raise KeyError(f"unknown continuation info key: {key!r}")
        fields[field] = _coerce(field, value)
    for key, value in kwargs.items():
        if key not in ContinueInfo.__dataclass_fields__:
            raise KeyError(f"unknown continuation info key: {key!r}")
        fields[key] = _coerce(key, value)
    return ContinueInfo(**fields)
