"""Completion discovery — poll scans, progress thread, waiter threads.

This is the *discovery* third of the engine split (see ``core.scheduler``
for the execution third; ``core.engine`` is the facade). It mirrors the
paper's "progress": completions are found either

* **push** — the completing thread publishes via ``Completable._complete``
  (host futures, transport matches, CR drains); no Progress involvement, or
* **poll** — poll-mode ops (``jax.Array`` readiness, timers, predicates)
  are discovered by ``scan()`` calls, which every engine entry point makes
  (``tick``, ``cr.test/wait``, transport calls) — the analogue of "any
  thread calling into MPI" finding the operation complete, or
* **waiters** — for CRs with ``thread="any"``, dedicated threads that
  *block* on array readiness (the MPI-internal progress thread analogue,
  and the "MPI progress for all" direction: discovery as a first-class,
  swappable service rather than a side effect of application calls).

The optional internal progress thread periodically scans and drains the
scheduler, so completions are noticed even if no application thread calls
into the engine.
"""
from __future__ import annotations

import queue as queue_mod
import threading
from typing import List, Optional

from repro.core.completable import ArrayOp, Completable
from repro.core.scheduler import Scheduler
from repro.obs import events as _obs_events
from repro.obs import tracer as _obs


class Progress:
    """Discovery component: owns the poll list and the internal threads."""

    def __init__(self, scheduler: Scheduler, *,
                 progress_thread: bool = False,
                 progress_interval: float = 2e-4,
                 n_waiters: int = 0) -> None:
        self.scheduler = scheduler
        self._poll_ops: List[Completable] = []
        self._poll_lock = threading.Lock()
        self._shutdown = threading.Event()
        self.stats = {"poll_scans": 0, "waiter_blocks": 0}
        self._progress_thread: Optional[threading.Thread] = None
        if progress_thread:
            self._progress_thread = threading.Thread(
                target=self._progress_loop, args=(progress_interval,),
                name="contin-progress", daemon=True)
            self._progress_thread.start()
        self._waiter_q: "queue_mod.Queue[Optional[ArrayOp]]" = queue_mod.Queue()
        self._waiters = [
            threading.Thread(target=self._waiter_loop,
                             name=f"contin-waiter-{i}", daemon=True)
            for i in range(n_waiters)]
        for w in self._waiters:
            w.start()

    @property
    def has_waiters(self) -> bool:
        return bool(self._waiters)

    # ------------------------------------------------------------- tracking
    def watch(self, op: Completable, *, use_waiter: bool = False) -> None:
        """Track a pending poll-mode op until a scan discovers it complete.

        ``use_waiter`` hands ``ArrayOp``s to a blocking waiter thread
        instead (callers gate this on the CR's ``thread=any`` policy).
        """
        if use_waiter and self._waiters and isinstance(op, ArrayOp):
            self._waiter_q.put(op)
            return
        with self._poll_lock:
            self._poll_ops.append(op)

    def scan(self) -> None:
        """Discover completions of poll-mode ops (cheap, lock-sliced)."""
        self.stats["poll_scans"] += 1
        tr = _obs.TRACE
        t0 = tr.now() if tr is not None else 0.0
        with self._poll_lock:
            ops = list(self._poll_ops)
        done_ops = [op for op in ops if op.done()]  # done() fires hooks
        if done_ops:
            done_set = set(map(id, done_ops))
            with self._poll_lock:
                self._poll_ops = [op for op in self._poll_ops
                                  if id(op) not in done_set]
            # only fruitful scans are recorded — empty polls would swamp
            # the ring without telling the timeline anything
            if tr is not None:
                tr.evt(_obs_events.PROGRESS_SCAN, -1, "core", ts=t0,
                       dur=tr.now() - t0, meta=len(done_ops))

    @property
    def watched(self) -> int:
        with self._poll_lock:
            return len(self._poll_ops)

    # ------------------------------------------------------ internal threads
    def _progress_loop(self, interval: float) -> None:
        self.scheduler.register_internal_thread()
        while not self._shutdown.wait(interval):
            self.scan()
            self.scheduler.drain()

    def _waiter_loop(self) -> None:
        self.scheduler.register_internal_thread()
        while True:
            op = self._waiter_q.get()
            if op is None or self._shutdown.is_set():
                break
            self.stats["waiter_blocks"] += 1
            op.block()               # fires hooks on this internal thread
            self.scheduler.drain()   # eligible only for thread=any CRs

    # -------------------------------------------------------------- lifecycle
    def shutdown(self) -> None:
        self._shutdown.set()
        for _ in self._waiters:
            self._waiter_q.put(None)
        for w in self._waiters:
            w.join(timeout=2.0)
        if self._progress_thread is not None:
            self._progress_thread.join(timeout=2.0)
