"""MPI-Continuations-style completion-notification runtime for JAX.

The paper's primary contribution, adapted per DESIGN.md §2. Public API
(names mirror the paper's interface):

    engine = Engine()                      # or default_engine()
    cr = engine.continue_init(info)        # MPIX_Continue_init (defaults)
    flag = engine.continue_when(op, cb, cb_data, status, cr, flags)  # MPIX_Continue
    flag = engine.continue_all(ops, cb, cb_data, statuses, cr, flags)  # MPIX_Continueall
    flag = engine.continue_any(ops, cb, ..., indices=idx, cr=cr)   # Testany-style
    flag = engine.continue_some(ops, k, cb, ..., indices=idx, cr=cr)  # Waitsome-style
    cr.test() / cr.wait() / cr.free()      # MPI_Test / MPI_Wait / Request_free

Per-registration ``ContinueFlags`` override the CR's info defaults
(``core.flags``); ``when_all``/``when_any``/``when_some`` compose ops into
new ``Completable``s; ``engine.wrap(op)`` lifts an op into an awaitable,
chainable ``Promise`` (``core.promise``).
"""
from repro.core.completable import (ArrayOp, CombinedOp, Completable,
                                    HostTaskOp, PredicateOp, TimerOp,
                                    when_all, when_any, when_some)
from repro.core.continuation import (CallbackError, ConcurrentCompletionError,
                                     Continuation, ContinuationRequest,
                                     CRState)
from repro.core.engine import Engine, default_engine, reset_default_engine
from repro.core.flags import ContinueFlags, ResolvedPolicy, make_flags
from repro.core.info import (THREAD_ANY, THREAD_APPLICATION, ContinueInfo,
                             make_info)
from repro.core.progress import Progress
from repro.core.promise import Promise, PromiseCancelled, Signal
from repro.core.scheduler import (AffinityScheduler, FifoScheduler, Scheduler,
                                  make_scheduler)
from repro.core.status import STATUS_IGNORE, OpState, Status
from repro.core.testsome import TestsomeManager
from repro.core.transport import ANY_SOURCE, ANY_TAG, RecvOp, SendOp, Transport

__all__ = [
    "ArrayOp", "CombinedOp", "Completable", "HostTaskOp", "PredicateOp",
    "TimerOp", "when_all", "when_any", "when_some",
    "CallbackError", "ConcurrentCompletionError", "Continuation",
    "ContinuationRequest", "CRState", "Engine", "default_engine",
    "reset_default_engine", "THREAD_ANY", "THREAD_APPLICATION",
    "ContinueInfo", "make_info", "ContinueFlags", "ResolvedPolicy",
    "make_flags", "STATUS_IGNORE", "OpState", "Status",
    "Progress", "Promise", "PromiseCancelled", "Signal", "Scheduler",
    "FifoScheduler",
    "AffinityScheduler", "make_scheduler", "TestsomeManager", "ANY_SOURCE",
    "ANY_TAG", "RecvOp", "SendOp", "Transport",
]
