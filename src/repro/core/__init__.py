"""MPI-Continuations-style completion-notification runtime for JAX.

The paper's primary contribution, adapted per DESIGN.md §2. Public API
(names mirror the paper's interface):

    engine = Engine()                      # or default_engine()
    cr = engine.continue_init(info)        # MPIX_Continue_init
    flag = engine.continue_when(op, cb, cb_data, status, cr)    # MPIX_Continue
    flag = engine.continue_all(ops, cb, cb_data, statuses, cr)  # MPIX_Continueall
    cr.test() / cr.wait() / cr.free()      # MPI_Test / MPI_Wait / Request_free
"""
from repro.core.completable import (ArrayOp, Completable, HostTaskOp,
                                    PredicateOp, TimerOp)
from repro.core.continuation import (CallbackError, ConcurrentCompletionError,
                                     Continuation, ContinuationRequest,
                                     CRState)
from repro.core.engine import Engine, default_engine, reset_default_engine
from repro.core.info import (THREAD_ANY, THREAD_APPLICATION, ContinueInfo,
                             make_info)
from repro.core.progress import Progress
from repro.core.scheduler import (AffinityScheduler, FifoScheduler, Scheduler,
                                  make_scheduler)
from repro.core.status import STATUS_IGNORE, OpState, Status
from repro.core.testsome import TestsomeManager
from repro.core.transport import ANY_SOURCE, ANY_TAG, RecvOp, SendOp, Transport

__all__ = [
    "ArrayOp", "Completable", "HostTaskOp", "PredicateOp", "TimerOp",
    "CallbackError", "ConcurrentCompletionError", "Continuation",
    "ContinuationRequest", "CRState", "Engine", "default_engine",
    "reset_default_engine", "THREAD_ANY", "THREAD_APPLICATION",
    "ContinueInfo", "make_info", "STATUS_IGNORE", "OpState", "Status",
    "Progress", "Scheduler", "FifoScheduler", "AffinityScheduler",
    "make_scheduler", "TestsomeManager", "ANY_SOURCE", "ANY_TAG", "RecvOp",
    "SendOp", "Transport",
]
