"""In-process multi-rank message transport.

The container is a single process, so "ranks" are threads and the network is
a set of mailboxes with MPI-style two-sided matching (source/tag, wildcards,
non-overtaking per (src,dst,tag)). Semantics kept from MPI where they matter
to the paper:

* non-blocking ``isend``/``irecv`` returning completable ops,
* eager vs. rendezvous send completion (``eager_threshold``),
* receive cancellation (→ cancelled status observed by callbacks,
  paper Listing 4),
* completion discovered *inside* a transport call fires continuation hooks on
  the calling thread — the analogue of "continuations may be invoked as soon
  as any thread calls into MPI" (paper §3),
* optional simulated link latency via a background delivery thread, so
  completions are genuinely asynchronous in benchmarks.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Callable, List, Optional, Tuple

from repro.core.completable import Completable
from repro.core.status import OpState, Status

ANY_SOURCE = -1
ANY_TAG = -1


def _payload_nbytes(payload: Any) -> int:
    """Wire size of a payload for eager/rendezvous choice and accounting.

    Array-likes report their ``nbytes`` (typed messages may expose a
    computed ``nbytes`` property covering their array fields); containers
    sum their elements plus a small framing constant, so a KV-block
    message carried as a dict/tuple of device arrays is accounted at its
    real payload size rather than the control-message default."""
    if isinstance(payload, (bytes, bytearray, memoryview)):
        return len(payload)
    nbytes = getattr(payload, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    if isinstance(payload, (list, tuple)):
        return 16 + sum(_payload_nbytes(v) for v in payload)
    if isinstance(payload, dict):
        return 16 + sum(_payload_nbytes(v) for v in payload.values())
    return 64  # control-message default


class MessageOp(Completable):
    """Base for send/recv handles."""

    def __init__(self, transport: "Transport") -> None:
        super().__init__()
        self._transport = transport

    def _poll(self) -> bool:
        # Message completion is push-based (delivered by the matcher).
        return False

    @property
    def supports_push(self) -> bool:
        return True


class SendOp(MessageOp):
    def __init__(self, transport: "Transport", source: int, dest: int,
                 tag: int, payload: Any) -> None:
        super().__init__(transport)
        self.source, self.dest, self.tag = source, dest, tag
        self.payload = payload
        self.nbytes = _payload_nbytes(payload)


class RecvOp(MessageOp):
    def __init__(self, transport: "Transport", rank: int, source: int,
                 tag: int) -> None:
        super().__init__(transport)
        self.rank, self.source, self.tag = rank, source, tag

    def matches(self, src: int, tag: int) -> bool:
        return ((self.source == ANY_SOURCE or self.source == src)
                and (self.tag == ANY_TAG or self.tag == tag))

    def cancel(self) -> bool:
        """Remove a posted receive (paper §3.6); no-op if already matched.

        Complete-or-cancel is atomic against a concurrent ``_deliver``:
        either this call wins the matching race and the op completes
        CANCELLED, or the matcher won — in which case cancel() waits for
        the in-flight ``_finish_pair`` to publish the completion before
        returning False, so the caller never observes a receive that is
        neither matched nor cancelled. (The matcher removes the op from
        the posted list under the mailbox lock but completes it *after*
        releasing the lock; without the wait, a cancel landing in that
        window would return False while the op still reads PENDING.)"""
        if self._transport._cancel_recv(self):
            return self._complete(Status(cancelled=True), OpState.CANCELLED)
        # Not in the posted list: either already terminal, or popped by a
        # matcher whose _finish_pair has not run yet. Wait it out — the
        # matcher completes the op promptly and never blocks on us.
        while self.state is OpState.PENDING:
            time.sleep(1e-6)
        return False


class _Mailbox:
    """Per-rank matching state: posted receives + unexpected messages."""

    __slots__ = ("lock", "posted", "unexpected")

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.posted: List[RecvOp] = []
        self.unexpected: List[SendOp] = []


class Transport:
    def __init__(self, n_ranks: int, *, engine=None,
                 eager_threshold: int = 4096,
                 latency_s: float = 0.0) -> None:
        self.n_ranks = n_ranks
        self.engine = engine
        self.eager_threshold = eager_threshold
        self.latency_s = latency_s
        self._boxes = [_Mailbox() for _ in range(n_ranks)]
        self._stats_lock = threading.Lock()
        self._counters = {"sends": 0, "recvs": 0, "matches": 0,
                          "cancelled": 0}
        # per-tag traffic accounting: tag -> sent/received message and
        # byte counters (bytes via _payload_nbytes), so e.g. KV-shipping
        # bandwidth is observable per channel through stats()
        self._tag_counters: dict = {}
        self._shutdown = threading.Event()
        self._delivery: Optional[threading.Thread] = None
        if latency_s > 0:
            self._dq: list = []
            self._dq_seq = itertools.count()
            self._dq_lock = threading.Lock()
            self._dq_cv = threading.Condition(self._dq_lock)
            self._delivery = threading.Thread(
                target=self._delivery_loop, name="transport-delivery",
                daemon=True)
            self._delivery.start()

    # ------------------------------------------------------------------- API
    def isend(self, source: int, dest: int, tag: int, payload: Any) -> SendOp:
        op = SendOp(self, source, dest, tag, payload)
        with self._stats_lock:
            self._counters["sends"] += 1
            t = self._tag_counter(tag)
            t["sent_msgs"] += 1
            t["sent_bytes"] += op.nbytes
        if self.latency_s > 0:
            with self._dq_cv:
                heapq.heappush(self._dq, (time.monotonic() + self.latency_s,
                                          next(self._dq_seq), op))
                self._dq_cv.notify()
        else:
            self._deliver(op)
        self._on_enter()
        return op

    def irecv(self, rank: int, source: int = ANY_SOURCE,
              tag: int = ANY_TAG) -> RecvOp:
        op = RecvOp(self, rank, source, tag)
        with self._stats_lock:
            self._counters["recvs"] += 1
        box = self._boxes[rank]
        matched: Optional[SendOp] = None
        with box.lock:
            for i, send in enumerate(box.unexpected):
                if op.matches(send.source, send.tag):
                    matched = box.unexpected.pop(i)
                    break
            if matched is None:
                box.posted.append(op)
        if matched is not None:
            self._finish_pair(matched, op)
        self._on_enter()
        return op

    def cancel_posted(self, rank: int, source: int = ANY_SOURCE,
                      tag: int = ANY_TAG) -> int:
        """Cancel every receive posted at ``rank`` matching ``source``/
        ``tag`` (wildcards allowed); returns how many were cancelled.

        The failure-recovery primitive: when a peer is declared dead, its
        partner tears down the standing receives armed for that peer so
        their continuations observe CANCELLED (paper Listing 4) instead
        of waiting forever. Matching is evaluated against the *receive's*
        selectors — a recv posted with ``ANY_SOURCE`` is only swept by a
        wildcard ``source`` here, since a specific dead peer cannot claim
        a receive that other, live peers may still satisfy."""
        box = self._boxes[rank]
        with box.lock:
            victims = [op for op in box.posted
                       if (source == ANY_SOURCE or op.source == source)
                       and (tag == ANY_TAG or op.tag == tag)]
        cancelled = 0
        for op in victims:
            if op.cancel():
                cancelled += 1
        self._on_enter()
        return cancelled

    def send(self, source: int, dest: int, tag: int, payload: Any,
             timeout: float = 30.0) -> None:
        """Blocking convenience send."""
        op = self.isend(source, dest, tag, payload)
        self._block(op, timeout)

    def recv(self, rank: int, source: int = ANY_SOURCE, tag: int = ANY_TAG,
             timeout: float = 30.0) -> Status:
        op = self.irecv(rank, source, tag)
        self._block(op, timeout)
        return op.status

    # -------------------------------------------------------------- internals
    def _block(self, op: Completable, timeout: float) -> None:
        deadline = time.monotonic() + timeout
        while op.state is OpState.PENDING:
            if self.engine is not None:
                self.engine.tick()
            if time.monotonic() > deadline:
                raise TimeoutError("transport op timed out")
            time.sleep(1e-5)

    def _on_enter(self) -> None:
        """Run eligible ready continuations — 'thread inside MPI' semantics."""
        if self.engine is not None:
            self.engine.enter()

    def _deliver(self, send: SendOp) -> None:
        box = self._boxes[send.dest]
        matched: Optional[RecvOp] = None
        with box.lock:
            for i, recv in enumerate(box.posted):
                if recv.matches(send.source, send.tag):
                    matched = box.posted.pop(i)
                    break
            if matched is None:
                box.unexpected.append(send)
        if matched is not None:
            self._finish_pair(send, matched)
        elif send.nbytes <= self.eager_threshold:
            # Eager: buffered by the "network"; sender completes immediately.
            send._complete(Status(source=send.source, tag=send.tag,
                                  count=send.nbytes))

    def _tag_counter(self, tag: int) -> dict:
        """Per-tag counter bucket (caller holds ``_stats_lock``)."""
        c = self._tag_counters.get(tag)
        if c is None:
            c = self._tag_counters[tag] = {
                "sent_msgs": 0, "sent_bytes": 0,
                "recvd_msgs": 0, "recvd_bytes": 0}
        return c

    def stats(self) -> dict:
        """Snapshot of transport counters.

        Top-level op counts (``sends``/``recvs``/``matches``/
        ``cancelled``), total ``sent_bytes``/``recvd_bytes``, and a
        ``per_tag`` map of ``{tag: {sent_msgs, sent_bytes, recvd_msgs,
        recvd_bytes}}``. Received counters tick at match time (delivery),
        sent counters at post time."""
        with self._stats_lock:
            out = dict(self._counters)
            out["per_tag"] = {t: dict(c)
                              for t, c in self._tag_counters.items()}
        out["sent_bytes"] = sum(c["sent_bytes"]
                                for c in out["per_tag"].values())
        out["recvd_bytes"] = sum(c["recvd_bytes"]
                                 for c in out["per_tag"].values())
        return out

    def _finish_pair(self, send: SendOp, recv: RecvOp) -> None:
        with self._stats_lock:
            self._counters["matches"] += 1
            t = self._tag_counter(send.tag)
            t["recvd_msgs"] += 1
            t["recvd_bytes"] += send.nbytes
        recv._complete(Status(source=send.source, tag=send.tag,
                              payload=send.payload, count=send.nbytes))
        send._complete(Status(source=send.source, tag=send.tag,
                              count=send.nbytes))

    def _cancel_recv(self, op: RecvOp) -> bool:
        box = self._boxes[op.rank]
        with box.lock:
            try:
                box.posted.remove(op)
            except ValueError:
                return False
        with self._stats_lock:
            self._counters["cancelled"] += 1
        return True

    def _delivery_loop(self) -> None:
        if self.engine is not None:
            self.engine.register_internal_thread()
        while not self._shutdown.is_set():
            with self._dq_cv:
                while not self._dq and not self._shutdown.is_set():
                    self._dq_cv.wait(timeout=0.05)
                if self._shutdown.is_set():
                    return
                when, _, op = self._dq[0]
                now = time.monotonic()
                if when > now:
                    self._dq_cv.wait(timeout=when - now)
                    continue
                heapq.heappop(self._dq)
            self._deliver(op)

    def shutdown(self) -> None:
        self._shutdown.set()
        if self._delivery is not None:
            with self._dq_cv:
                self._dq_cv.notify_all()
            self._delivery.join(timeout=2.0)
