"""In-process multi-rank message transport.

The container is a single process, so "ranks" are threads and the network is
a set of mailboxes with MPI-style two-sided matching (source/tag, wildcards,
non-overtaking per (src,dst,tag)). Semantics kept from MPI where they matter
to the paper:

* non-blocking ``isend``/``irecv`` returning completable ops,
* eager vs. rendezvous send completion (``eager_threshold``),
* receive cancellation (→ cancelled status observed by callbacks,
  paper Listing 4),
* completion discovered *inside* a transport call fires continuation hooks on
  the calling thread — the analogue of "continuations may be invoked as soon
  as any thread calls into MPI" (paper §3),
* optional simulated link latency via a background delivery thread, so
  completions are genuinely asynchronous in benchmarks.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Callable, List, Optional, Tuple

from repro.core.completable import Completable
from repro.core.status import OpState, Status

ANY_SOURCE = -1
ANY_TAG = -1


def _payload_nbytes(payload: Any) -> int:
    if isinstance(payload, (bytes, bytearray, memoryview)):
        return len(payload)
    nbytes = getattr(payload, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    return 64  # control-message default


class MessageOp(Completable):
    """Base for send/recv handles."""

    def __init__(self, transport: "Transport") -> None:
        super().__init__()
        self._transport = transport

    def _poll(self) -> bool:
        # Message completion is push-based (delivered by the matcher).
        return False

    @property
    def supports_push(self) -> bool:
        return True


class SendOp(MessageOp):
    def __init__(self, transport: "Transport", source: int, dest: int,
                 tag: int, payload: Any) -> None:
        super().__init__(transport)
        self.source, self.dest, self.tag = source, dest, tag
        self.payload = payload
        self.nbytes = _payload_nbytes(payload)


class RecvOp(MessageOp):
    def __init__(self, transport: "Transport", rank: int, source: int,
                 tag: int) -> None:
        super().__init__(transport)
        self.rank, self.source, self.tag = rank, source, tag

    def matches(self, src: int, tag: int) -> bool:
        return ((self.source == ANY_SOURCE or self.source == src)
                and (self.tag == ANY_TAG or self.tag == tag))

    def cancel(self) -> bool:
        """Remove a posted receive (paper §3.6); no-op if already matched."""
        if self._transport._cancel_recv(self):
            return self._complete(Status(cancelled=True), OpState.CANCELLED)
        return False


class _Mailbox:
    """Per-rank matching state: posted receives + unexpected messages."""

    __slots__ = ("lock", "posted", "unexpected")

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.posted: List[RecvOp] = []
        self.unexpected: List[SendOp] = []


class Transport:
    def __init__(self, n_ranks: int, *, engine=None,
                 eager_threshold: int = 4096,
                 latency_s: float = 0.0) -> None:
        self.n_ranks = n_ranks
        self.engine = engine
        self.eager_threshold = eager_threshold
        self.latency_s = latency_s
        self._boxes = [_Mailbox() for _ in range(n_ranks)]
        self._stats_lock = threading.Lock()
        self.stats = {"sends": 0, "recvs": 0, "matches": 0, "cancelled": 0}
        self._shutdown = threading.Event()
        self._delivery: Optional[threading.Thread] = None
        if latency_s > 0:
            self._dq: list = []
            self._dq_seq = itertools.count()
            self._dq_lock = threading.Lock()
            self._dq_cv = threading.Condition(self._dq_lock)
            self._delivery = threading.Thread(
                target=self._delivery_loop, name="transport-delivery",
                daemon=True)
            self._delivery.start()

    # ------------------------------------------------------------------- API
    def isend(self, source: int, dest: int, tag: int, payload: Any) -> SendOp:
        op = SendOp(self, source, dest, tag, payload)
        with self._stats_lock:
            self.stats["sends"] += 1
        if self.latency_s > 0:
            with self._dq_cv:
                heapq.heappush(self._dq, (time.monotonic() + self.latency_s,
                                          next(self._dq_seq), op))
                self._dq_cv.notify()
        else:
            self._deliver(op)
        self._on_enter()
        return op

    def irecv(self, rank: int, source: int = ANY_SOURCE,
              tag: int = ANY_TAG) -> RecvOp:
        op = RecvOp(self, rank, source, tag)
        with self._stats_lock:
            self.stats["recvs"] += 1
        box = self._boxes[rank]
        matched: Optional[SendOp] = None
        with box.lock:
            for i, send in enumerate(box.unexpected):
                if op.matches(send.source, send.tag):
                    matched = box.unexpected.pop(i)
                    break
            if matched is None:
                box.posted.append(op)
        if matched is not None:
            self._finish_pair(matched, op)
        self._on_enter()
        return op

    def send(self, source: int, dest: int, tag: int, payload: Any,
             timeout: float = 30.0) -> None:
        """Blocking convenience send."""
        op = self.isend(source, dest, tag, payload)
        self._block(op, timeout)

    def recv(self, rank: int, source: int = ANY_SOURCE, tag: int = ANY_TAG,
             timeout: float = 30.0) -> Status:
        op = self.irecv(rank, source, tag)
        self._block(op, timeout)
        return op.status

    # -------------------------------------------------------------- internals
    def _block(self, op: Completable, timeout: float) -> None:
        deadline = time.monotonic() + timeout
        while op.state is OpState.PENDING:
            if self.engine is not None:
                self.engine.tick()
            if time.monotonic() > deadline:
                raise TimeoutError("transport op timed out")
            time.sleep(1e-5)

    def _on_enter(self) -> None:
        """Run eligible ready continuations — 'thread inside MPI' semantics."""
        if self.engine is not None:
            self.engine.enter()

    def _deliver(self, send: SendOp) -> None:
        box = self._boxes[send.dest]
        matched: Optional[RecvOp] = None
        with box.lock:
            for i, recv in enumerate(box.posted):
                if recv.matches(send.source, send.tag):
                    matched = box.posted.pop(i)
                    break
            if matched is None:
                box.unexpected.append(send)
        if matched is not None:
            self._finish_pair(send, matched)
        elif send.nbytes <= self.eager_threshold:
            # Eager: buffered by the "network"; sender completes immediately.
            send._complete(Status(source=send.source, tag=send.tag,
                                  count=send.nbytes))

    def _finish_pair(self, send: SendOp, recv: RecvOp) -> None:
        with self._stats_lock:
            self.stats["matches"] += 1
        recv._complete(Status(source=send.source, tag=send.tag,
                              payload=send.payload, count=send.nbytes))
        send._complete(Status(source=send.source, tag=send.tag,
                              count=send.nbytes))

    def _cancel_recv(self, op: RecvOp) -> bool:
        box = self._boxes[op.rank]
        with box.lock:
            try:
                box.posted.remove(op)
            except ValueError:
                return False
        with self._stats_lock:
            self.stats["cancelled"] += 1
        return True

    def _delivery_loop(self) -> None:
        if self.engine is not None:
            self.engine.register_internal_thread()
        while not self._shutdown.is_set():
            with self._dq_cv:
                while not self._dq and not self._shutdown.is_set():
                    self._dq_cv.wait(timeout=0.05)
                if self._shutdown.is_set():
                    return
                when, _, op = self._dq[0]
                now = time.monotonic()
                if when > now:
                    self._dq_cv.wait(timeout=when - now)
                    continue
                heapq.heappop(self._dq)
            self._deliver(op)

    def shutdown(self) -> None:
        self._shutdown.set()
        if self._delivery is not None:
            with self._dq_cv:
                self._dq_cv.notify_all()
            self._delivery.join(timeout=2.0)
