"""Event taxonomy for the observability subsystem.

Every trace record is a small tuple pushed into a per-thread ring buffer
(``obs.buffer.TraceBuffer``); this module names the event *kinds* and the
lifecycle *edges* so producers and exporters agree on vocabulary without
importing each other.

Two families:

* ``cont.*`` — the four continuation lifecycle edges the paper's latency
  claim is about: an operation is *posted* (continuation registered),
  the op group *completes* (continuation flips READY), the continuation
  is *enqueued* (CR private queue or scheduler ready queue), and the
  callback *runs*. Inter-edge latencies feed per-policy histograms
  (``LIFECYCLE_EDGES``).
* ``req.*`` — serve-layer span/instant events correlated by request id:
  admission, page alloc/release, prefill chunks, KV-block ship/import
  across the disagg transport, decode-step completion, token delivery,
  and the router's shadow-replay link (``req.link`` lets the exporter
  merge a shadow's events onto the original request's track).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, NamedTuple, Optional

# --------------------------------------------------------------- kinds
# continuation lifecycle (rid = continuation seqno)
CONT_POSTED = "cont.posted"
CONT_READY = "cont.ready"          # op group complete -> READY
CONT_ENQUEUED = "cont.enqueued"    # pushed to a ready queue
CONT_RAN = "cont.ran"              # span: callback execution
PROGRESS_SCAN = "progress.scan"    # span: a poll scan that found work

# serve layer (rid = request id)
REQ_SUBMIT = "req.submit"          # entered a tier's intake
REQ_ADMIT = "req.admit"            # span: arrival -> placed/seated
REQ_PAGES_ALLOC = "req.pages.alloc"
REQ_PAGES_RELEASE = "req.pages.release"
REQ_PREFILL = "req.prefill"        # span: prefill dispatch -> complete
REQ_KV_SHIP = "req.kv.ship"        # disagg: block left the prefill role
REQ_KV_IMPORT = "req.kv.import"    # disagg: block installed at decode
REQ_SEAT = "req.seat"              # disagg: landed request seated
REQ_STEP = "req.step"              # span: decode/verify step for this req
REQ_DELIVER = "req.deliver"        # tokens published to the request
REQ_FINISH = "req.finish"          # terminal state reached
REQ_LINK = "req.link"              # rid = shadow id, meta = original id
REQ_REPLAY = "req.replay"          # failover: requeued for replay

#: lifecycle-edge histogram names, in causal order. ``complete_to_run``
#: is the paper's notification latency (op complete -> callback ran).
EDGE_POST_TO_COMPLETE = "post_to_complete"
EDGE_COMPLETE_TO_ENQUEUE = "complete_to_enqueue"
EDGE_ENQUEUE_TO_RUN = "enqueue_to_run"
EDGE_COMPLETE_TO_RUN = "complete_to_run"
LIFECYCLE_EDGES = (EDGE_POST_TO_COMPLETE, EDGE_COMPLETE_TO_ENQUEUE,
                   EDGE_ENQUEUE_TO_RUN, EDGE_COMPLETE_TO_RUN)


class Event(NamedTuple):
    """A drained trace record (ring buffers store the raw 6-tuple)."""

    ts: float            # monotonic seconds (tracer clock)
    dur: float           # span duration in seconds; 0.0 for instants
    kind: str            # one of the constants above
    rid: int             # request id / continuation seqno; -1 if n/a
    src: str             # emitting component ("core", "engine", ...)
    meta: Any            # small per-kind payload (tuple/str/int/None)
    tid: int             # OS thread id of the recording thread


@lru_cache(maxsize=256)
def policy_key(policy) -> str:
    """Compact label for a ``ResolvedPolicy`` — the histogram axis.

    Cached per (frozen, hashable) policy instance; the serve engine's
    bounded ``_step_flags`` cache keeps the population small.
    """
    parts = ["poll" if policy.poll_only else "sched"]
    if policy.thread != "application":
        parts.append(policy.thread)
    if policy.enqueue_complete:
        parts.append("enq")
    if policy.defer_complete:
        parts.append("defer")
    if policy.immediate:
        parts.append("imm")
    if policy.priority:
        parts.append(f"pr{policy.priority}")
    return "|".join(parts)


def link_roots(events) -> dict:
    """Resolve ``req.link`` chains to each request's original id.

    Router failover may re-shadow a shadow; follow links transitively so
    every replayed generation collapses onto one correlated track.
    """
    parent: dict[int, int] = {}
    for ev in events:
        if ev.kind == REQ_LINK and isinstance(ev.meta, int):
            parent[ev.rid] = ev.meta

    def root(rid: int) -> int:
        seen = set()
        while rid in parent and rid not in seen:
            seen.add(rid)
            rid = parent[rid]
        return rid

    return {rid: root(rid) for rid in parent}
