"""The global tracer: default-off, sampling, per-thread rings, histograms.

Instrumentation sites across the runtime follow one pattern::

    from repro.obs import tracer as _obs
    ...
    tr = _obs.TRACE
    if tr is not None and tr.want(rid):
        tr.evt(kind, rid, "engine", meta=...)

``TRACE`` is ``None`` unless tracing was started, so the default-off hot
path costs one module-attribute load and a ``None`` check. Continuation
lifecycle sites additionally gate on ``cont.t_posted is not None`` — a
continuation is traced end-to-end iff it was sampled at registration,
which keeps the per-edge decision to a single attribute test.

Sampling is deterministic by id (Knuth multiplicative hash), so every
component traces the *same* subset of requests/continuations and
timelines stay complete under sampling.

Enable programmatically (``obs.start(sample=...)``) or via the
environment: ``REPRO_TRACE=1`` (optionally ``REPRO_TRACE_SAMPLE=0.25``,
``REPRO_TRACE_CAPACITY=65536``) arms tracing at import time, which is
how ``examples/serve_trace.py`` and ad-hoc runs switch it on without
code changes.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.obs.buffer import TraceBuffer
from repro.obs.events import (CONT_RAN, EDGE_COMPLETE_TO_ENQUEUE,
                              EDGE_COMPLETE_TO_RUN, EDGE_ENQUEUE_TO_RUN,
                              EDGE_POST_TO_COMPLETE, Event, policy_key)
from repro.obs.hist import Histogram

DEFAULT_CAPACITY = 1 << 16


class Tracer:
    """One tracing session: buffers, histograms, clock, sampling."""

    def __init__(self, *, capacity: int = DEFAULT_CAPACITY,
                 sample: float = 1.0) -> None:
        if not 0.0 <= sample <= 1.0:
            raise ValueError(f"sample must be in [0, 1], got {sample}")
        self.capacity = capacity
        self.sample = sample
        self._threshold = int(sample * 0xFFFFFFFF)
        self.clock = time.monotonic   # matches Request arrival/token stamps
        self._tls = threading.local()
        self._buffers: List[TraceBuffer] = []
        self._buffers_lock = threading.Lock()
        self._hist: Dict[Tuple[str, str], Histogram] = {}
        self._hist_lock = threading.Lock()
        self.t0 = self.clock()

    # ------------------------------------------------------------ recording
    def now(self) -> float:
        return self.clock()

    def want(self, rid: int) -> bool:
        """Deterministic per-id sampling decision."""
        if self.sample >= 1.0:
            return True
        if self.sample <= 0.0:
            return False
        return ((rid * 2654435761) & 0xFFFFFFFF) <= self._threshold

    def _buf(self) -> TraceBuffer:
        buf = getattr(self._tls, "buf", None)
        if buf is None:
            buf = TraceBuffer(self.capacity)
            self._tls.buf = buf
            with self._buffers_lock:
                self._buffers.append(buf)
        return buf

    def evt(self, kind: str, rid: int = -1, src: str = "", *,
            dur: float = 0.0, meta=None, ts: Optional[float] = None) -> None:
        """Record one event on the calling thread's ring (never blocks)."""
        if ts is None:
            ts = self.clock()
        self._buf().record((ts, dur, kind, rid, src, meta))

    # ------------------------------------------------- lifecycle histograms
    def observe(self, edge: str, pkey: str, seconds: float) -> None:
        key = (edge, pkey)
        h = self._hist.get(key)
        if h is None:
            with self._hist_lock:
                h = self._hist.setdefault(key, Histogram())
        h.observe(seconds * 1e6)

    def lifecycle_ran(self, cont, t_run: float) -> None:
        """The callback-ran edge: emit the span + all inter-edge latencies.

        Called by ``Scheduler.run_one`` after the callback returns, only
        for continuations stamped at registration (``t_posted`` set).
        """
        t_end = self.clock()
        pkey = policy_key(cont.policy)
        self.evt(CONT_RAN, cont.seqno, "core", ts=t_run, dur=t_end - t_run,
                 meta=pkey)
        t_posted, t_ready = cont.t_posted, cont.t_ready
        t_enq = cont.t_enqueued
        if t_ready is not None:
            if t_posted is not None:
                self.observe(EDGE_POST_TO_COMPLETE, pkey, t_ready - t_posted)
            self.observe(EDGE_COMPLETE_TO_RUN, pkey, t_run - t_ready)
            if t_enq is not None:
                self.observe(EDGE_COMPLETE_TO_ENQUEUE, pkey, t_enq - t_ready)
        if t_enq is not None:
            self.observe(EDGE_ENQUEUE_TO_RUN, pkey, t_run - t_enq)

    # -------------------------------------------------------------- reading
    @property
    def dropped(self) -> int:
        with self._buffers_lock:
            bufs = list(self._buffers)
        return sum(b.dropped for b in bufs)

    def drain(self) -> List[Event]:
        """Merged, time-sorted snapshot of every thread's ring."""
        with self._buffers_lock:
            bufs = list(self._buffers)
        events: List[Event] = []
        for b in bufs:
            events.extend(b.snapshot())
        events.sort(key=lambda ev: ev.ts)
        return events

    def histograms(self) -> Dict[Tuple[str, str], Histogram]:
        with self._hist_lock:
            return dict(self._hist)


#: the global tracing session; ``None`` = tracing off (the common case).
TRACE: Optional[Tracer] = None
_state_lock = threading.Lock()


def start(*, capacity: int = DEFAULT_CAPACITY,
          sample: float = 1.0) -> Tracer:
    """Arm tracing globally; returns the (new) active ``Tracer``."""
    global TRACE
    with _state_lock:
        TRACE = Tracer(capacity=capacity, sample=sample)
        return TRACE


def stop() -> Optional[Tracer]:
    """Disarm tracing; returns the finished session (drain it for data)."""
    global TRACE
    with _state_lock:
        tr, TRACE = TRACE, None
        return tr


def active() -> Optional[Tracer]:
    return TRACE


def is_enabled() -> bool:
    return TRACE is not None


if os.environ.get("REPRO_TRACE", "") not in ("", "0"):  # pragma: no cover
    start(sample=float(os.environ.get("REPRO_TRACE_SAMPLE", "1.0")),
          capacity=int(os.environ.get("REPRO_TRACE_CAPACITY",
                                      str(DEFAULT_CAPACITY))))
