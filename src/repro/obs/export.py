"""Exporters: Chrome/Perfetto ``trace_event`` JSON + Prometheus text.

``chrome_trace`` renders drained events as the Trace Event Format both
``chrome://tracing`` and https://ui.perfetto.dev load directly: one
"process" per correlated request (shadow requests collapse onto their
original via ``req.link``), with a named "thread" row per emitting
component, so a request's admission -> prefill -> ship/import -> steps
-> delivery reads left-to-right on one track. Runtime-internal events
(``cont.*``, ``progress.*``) land in a shared pid 0 process keyed by
real thread id.

``prometheus_text`` renders a point-in-time text-exposition snapshot:
serve/transport counters as gauges plus the lifecycle histograms in
cumulative-bucket form.
"""
from __future__ import annotations

import re
from numbers import Number
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

from repro.obs.events import Event, link_roots
from repro.obs.hist import BOUNDS, Histogram

#: stable row order for the per-request component threads.
_SRC_ROWS = ("client", "router", "engine", "prefill", "decode", "serve",
             "core", "bench")


def _track(src: str) -> int:
    try:
        return _SRC_ROWS.index(src) + 1
    except ValueError:
        return len(_SRC_ROWS) + 1


def chrome_trace(events: Iterable[Event], *,
                 histograms: Optional[Mapping[Tuple[str, str],
                                              Histogram]] = None,
                 dropped: int = 0) -> dict:
    """Events -> a ``{"traceEvents": [...]}`` document (JSON-serializable)."""
    events = list(events)
    roots = link_roots(events)
    t0 = min((ev.ts for ev in events), default=0.0)
    out: List[dict] = []
    seen_pids: Dict[int, str] = {}
    seen_tids: set = set()

    def _meta(pid: int, name: str) -> None:
        if pid not in seen_pids:
            seen_pids[pid] = name
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "args": {"name": name}})

    def _tmeta(pid: int, tid: int, name: str) -> None:
        if (pid, tid) not in seen_tids:
            seen_tids.add((pid, tid))
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": name}})

    for ev in events:
        if ev.kind.startswith("req.") and ev.rid >= 0:
            rid = roots.get(ev.rid, ev.rid)
            pid = rid + 1                       # pid 0 is the runtime
            _meta(pid, f"request {rid}")
            tid = _track(ev.src)
            _tmeta(pid, tid, ev.src or "serve")
        else:
            pid = 0
            _meta(pid, "runtime")
            tid = ev.tid
            _tmeta(pid, tid, f"thread {tid}")
        rec = {"name": ev.kind, "cat": ev.kind.split(".")[0],
               "pid": pid, "tid": tid,
               "ts": round((ev.ts - t0) * 1e6, 3),
               "args": {"rid": ev.rid, "meta": _jsonable(ev.meta)}}
        if ev.dur > 0.0:
            rec["ph"] = "X"
            rec["dur"] = round(ev.dur * 1e6, 3)
        else:
            rec["ph"] = "i"
            rec["s"] = "t"
        out.append(rec)

    doc = {"traceEvents": out, "displayTimeUnit": "ms",
           "otherData": {"dropped_events": dropped,
                         "event_count": len(events)}}
    if histograms:
        doc["otherData"]["lifecycle_histograms"] = {
            f"{edge}|{pkey}": h.to_dict()
            for (edge, pkey), h in sorted(histograms.items())}
    return doc


def _jsonable(meta):
    if meta is None or isinstance(meta, (int, float, str, bool)):
        return meta
    if isinstance(meta, (list, tuple)):
        return [_jsonable(m) for m in meta]
    if isinstance(meta, dict):
        return {str(k): _jsonable(v) for k, v in meta.items()}
    return repr(meta)


# ------------------------------------------------------------- prometheus
_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _san(name: str) -> str:
    return _NAME_RE.sub("_", str(name))


def prometheus_text(metrics: Optional[Mapping] = None, *,
                    histograms: Optional[Mapping[Tuple[str, str],
                                                 Histogram]] = None,
                    dropped: int = 0,
                    transport: Optional[Mapping] = None,
                    prefix: str = "repro") -> str:
    """Text-exposition snapshot unifying serve metrics, transport
    counters, and the lifecycle histograms.

    ``metrics`` is any scalar mapping (a ``ServeMetrics`` works as-is);
    ``transport`` takes a ``Transport.stats()`` dict and expands the
    ``per_tag`` map into labelled counters.
    """
    lines: List[str] = []

    def gauge(name: str, value, labels: str = "") -> None:
        if isinstance(value, bool) or not isinstance(value, Number):
            return
        lines.append(f"{prefix}_{name}{labels} {float(value):g}")

    lines.append(f"# TYPE {prefix}_trace_dropped_events counter")
    gauge("trace_dropped_events", dropped)

    if metrics:
        lines.append(f"# TYPE {prefix}_serve gauge")
        for key, value in metrics.items():
            gauge(f"serve_{_san(key)}", value)

    if transport:
        lines.append(f"# TYPE {prefix}_transport counter")
        for key, value in transport.items():
            if key == "per_tag":
                for tag, counters in value.items():
                    for cname, cval in counters.items():
                        gauge(f"transport_{_san(cname)}", cval,
                              f'{{tag="{tag}"}}')
            else:
                gauge(f"transport_{_san(key)}", value)

    if histograms:
        hname = f"{prefix}_lifecycle_latency_us"
        lines.append(f"# TYPE {hname} histogram")
        for (edge, pkey), h in sorted(histograms.items()):
            base = f'edge="{_san(edge)}",policy="{pkey}"'
            cum = 0
            for i, count in enumerate(h.counts):
                cum += count
                le = f"{BOUNDS[i]:g}" if i < len(BOUNDS) else "+Inf"
                lines.append(f'{hname}_bucket{{{base},le="{le}"}} {cum}')
            lines.append(f"{hname}_sum{{{base}}} {h.total:g}")
            lines.append(f"{hname}_count{{{base}}} {h.count}")
    return "\n".join(lines) + "\n"
