"""Continuation-runtime observability: tracing, timelines, metrics export.

The paper's claim — low-latency in-runtime completion notification — is
only verifiable if the runtime can account for where each microsecond
between "operation complete" and "callback ran" goes. This subsystem
provides that accounting with near-zero cost when disabled:

* ``obs.tracer`` — the global default-off ``Tracer``: lock-free
  per-thread ring buffers (``obs.buffer.TraceBuffer``, drop-not-block on
  overflow with a surfaced drop counter), deterministic id-hash
  sampling, and per-policy lifecycle histograms (``obs.hist``).
* ``obs.events`` — the event taxonomy: the four continuation lifecycle
  edges (posted -> completed -> enqueued -> ran) and the serve-layer
  ``req.*`` spans correlated by request id across disagg roles and
  router shadow-replays (``req.link``).
* ``obs.export`` — Chrome/Perfetto ``trace_event`` JSON timelines and a
  Prometheus-style text snapshot unifying ``ServeMetrics`` and
  ``Transport.stats()``.
* ``obs.recorder`` — the ``Recorder`` handle the bench ``Replayer``
  attaches to trace measured samples and attribute SLO outcomes to
  runtime-internal causes (queue delay vs compute vs shipping).

Usage::

    from repro import obs

    obs.start(sample=1.0)          # or REPRO_TRACE=1 in the environment
    ... run traced work ...
    tr = obs.stop()
    doc = obs.chrome_trace(tr.drain(), histograms=tr.histograms(),
                           dropped=tr.dropped)
"""
from repro.obs.buffer import TraceBuffer
from repro.obs.events import (CONT_ENQUEUED, CONT_POSTED, CONT_RAN,
                              CONT_READY, LIFECYCLE_EDGES, Event, link_roots,
                              policy_key)
from repro.obs.export import chrome_trace, prometheus_text
from repro.obs.hist import Histogram
from repro.obs.recorder import Recorder
from repro.obs.tracer import (Tracer, active, is_enabled, start, stop)

__all__ = [
    "TraceBuffer", "Event", "Histogram", "Tracer", "Recorder",
    "CONT_POSTED", "CONT_READY", "CONT_ENQUEUED", "CONT_RAN",
    "LIFECYCLE_EDGES", "link_roots", "policy_key",
    "chrome_trace", "prometheus_text",
    "active", "is_enabled", "start", "stop",
]
