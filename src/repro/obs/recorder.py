"""Recorder — a detachable tracing session handle for harness code.

The bench ``Replayer`` (and anything else that wants "trace exactly this
window") attaches a ``Recorder``: entering starts a fresh global tracing
session, exiting drains it into the recorder's accumulated events and
histograms. Multiple start/stop cycles accumulate, so a replayer can
trace only its *measured* samples while warmup stays untraced.

Besides raw export (``chrome_trace`` / ``write`` / ``prometheus``), the
recorder aggregates per-request *cause* attribution for SLO reports:
how much of the observed latency was scheduler queue delay vs compute
(prefill + decode-step spans) vs KV shipping across the disagg
transport.
"""
from __future__ import annotations

import json
from collections import Counter
from typing import Dict, List, Mapping, Optional, Tuple

from repro.obs import export, tracer
from repro.obs.events import (EDGE_COMPLETE_TO_RUN, REQ_ADMIT, REQ_KV_IMPORT,
                              REQ_KV_SHIP, REQ_PREFILL, REQ_STEP, Event)
from repro.obs.hist import Histogram


class Recorder:
    """Accumulating trace session: start/stop (or ``with``) around the
    window of interest, then export or summarize."""

    def __init__(self, *, sample: float = 1.0,
                 capacity: int = tracer.DEFAULT_CAPACITY) -> None:
        self.sample = sample
        self.capacity = capacity
        self.events: List[Event] = []
        self.histograms: Dict[Tuple[str, str], Histogram] = {}
        self.dropped = 0
        self._active = False

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "Recorder":
        if self._active:
            return self
        tracer.start(sample=self.sample, capacity=self.capacity)
        self._active = True
        return self

    def stop(self) -> "Recorder":
        if not self._active:
            return self
        self._active = False
        tr = tracer.stop()
        if tr is not None:
            self.dropped += tr.dropped
            self.events.extend(tr.drain())
            for key, h in tr.histograms().items():
                mine = self.histograms.setdefault(key, Histogram())
                mine.merge(h)
        return self

    def __enter__(self) -> "Recorder":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    # -------------------------------------------------------------- export
    def chrome_trace(self) -> dict:
        return export.chrome_trace(self.events, histograms=self.histograms,
                                   dropped=self.dropped)

    def write(self, path: str) -> str:
        """Write the Chrome/Perfetto trace JSON; returns the path."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path

    def prometheus(self, metrics: Optional[Mapping] = None,
                   transport: Optional[Mapping] = None) -> str:
        return export.prometheus_text(metrics, histograms=self.histograms,
                                      dropped=self.dropped,
                                      transport=transport)

    # ------------------------------------------------------------- analysis
    def by_kind(self) -> Counter:
        return Counter(ev.kind for ev in self.events)

    def cause_summary(self) -> dict:
        """Where request time went: queue delay vs compute vs shipping.

        Returns mean milliseconds per request for each cause, plus the
        notification-latency mean so SLO reports can cite the runtime's
        own contribution.
        """
        admit: Dict[int, float] = {}
        compute: Dict[int, float] = {}
        ship_t: Dict[Tuple[int, object], float] = {}
        ship_gap: Dict[int, float] = {}
        for ev in self.events:
            if ev.kind == REQ_ADMIT:
                admit[ev.rid] = admit.get(ev.rid, 0.0) + ev.dur
            elif ev.kind in (REQ_STEP, REQ_PREFILL):
                compute[ev.rid] = compute.get(ev.rid, 0.0) + ev.dur
            elif ev.kind == REQ_KV_SHIP:
                ship_t[(ev.rid, _block(ev.meta))] = ev.ts
            elif ev.kind == REQ_KV_IMPORT:
                t_ship = ship_t.get((ev.rid, _block(ev.meta)))
                if t_ship is not None:
                    ship_gap[ev.rid] = (ship_gap.get(ev.rid, 0.0)
                                        + max(0.0, ev.ts - t_ship))

        def mean_ms(d: Dict) -> float:
            return (sum(d.values()) / len(d) * 1e3) if d else 0.0

        notify_us = 0.0
        n = 0
        for (edge, _), h in self.histograms.items():
            if edge == EDGE_COMPLETE_TO_RUN:
                notify_us += h.total
                n += h.count
        return {"requests": len(set(admit) | set(compute)),
                "queue_delay_ms_mean": round(mean_ms(admit), 3),
                "compute_ms_mean": round(mean_ms(compute), 3),
                "shipping_ms_mean": round(mean_ms(ship_gap), 3),
                "notify_latency_us_mean": round(notify_us / n, 3) if n else 0.0,
                "events": len(self.events), "dropped": self.dropped}


def _block(meta):
    if isinstance(meta, (list, tuple)) and meta:
        return meta[0]
    return meta
