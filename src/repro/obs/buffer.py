"""Bounded per-thread trace ring: drop-not-block on overflow.

One ``TraceBuffer`` per recording thread (the tracer hands them out via
``threading.local``), so the hot path is an unlocked list append by the
owning thread. When the buffer is full, new records are *dropped* and
counted — recording must never block or grow unboundedly, whatever the
consumer is doing (the decode loop records from inside completion
continuations; a stall there is a stall of the whole engine).

Draining snapshots the list from another thread. CPython list append /
``list(...)`` are atomic under the GIL, so the snapshot is a consistent
prefix without any lock on the recording side.
"""
from __future__ import annotations

import threading
from typing import List, Tuple

from repro.obs.events import Event

#: raw ring record: (ts, dur, kind, rid, src, meta) — Event minus tid.
Record = Tuple[float, float, str, int, str, object]


class TraceBuffer:
    """Single-writer bounded event list with a drop counter."""

    __slots__ = ("capacity", "events", "dropped", "tid")

    def __init__(self, capacity: int) -> None:
        self.capacity = capacity
        self.events: List[Record] = []
        self.dropped = 0
        self.tid = threading.get_ident()

    def record(self, rec: Record) -> None:
        """Append one record; drop (and count) when full. Never blocks."""
        if len(self.events) < self.capacity:
            self.events.append(rec)
        else:
            self.dropped += 1

    def snapshot(self) -> List[Event]:
        """Consistent copy as ``Event``s (safe from any thread)."""
        tid = self.tid
        return [Event(*rec, tid) for rec in list(self.events)]

    def __len__(self) -> int:
        return len(self.events)
