"""Log2-bucketed latency histograms (microsecond domain).

Fixed power-of-two bucket bounds from 1 us to ~8.4 s: notification
latencies span nanoseconds (inline execution) to seconds (a starved
poll_only queue), so log buckets hold the whole range in 25 ints. A
single short lock per observe keeps counts exact across threads — the
histogram path only runs while tracing is enabled, and the CI overhead
gate bounds its cost.
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Dict, List, Optional

#: upper bucket bounds in microseconds; the final +inf bucket is implicit.
BOUNDS: List[float] = [float(2 ** i) for i in range(24)]


class Histogram:
    """Latency histogram over microseconds with exact sum/count/max."""

    __slots__ = ("counts", "total", "count", "max", "_lock")

    def __init__(self) -> None:
        self.counts = [0] * (len(BOUNDS) + 1)
        self.total = 0.0       # sum of observed values (us)
        self.count = 0
        self.max = 0.0
        self._lock = threading.Lock()

    def observe(self, value_us: float) -> None:
        idx = bisect_left(BOUNDS, value_us)
        with self._lock:
            self.counts[idx] += 1
            self.total += value_us
            self.count += 1
            if value_us > self.max:
                self.max = value_us

    def merge(self, other: "Histogram") -> None:
        with self._lock:
            for i, c in enumerate(other.counts):
                self.counts[i] += c
            self.total += other.total
            self.count += other.count
            self.max = max(self.max, other.max)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Upper bound of the bucket containing quantile ``q`` (0..1)."""
        if not self.count:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return BOUNDS[i] if i < len(BOUNDS) else self.max
        return self.max

    def to_dict(self) -> Dict[str, float]:
        return {"count": self.count, "mean_us": round(self.mean, 3),
                "p50_us": self.percentile(0.50),
                "p99_us": self.percentile(0.99),
                "max_us": round(self.max, 3)}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Histogram({self.to_dict()})"
