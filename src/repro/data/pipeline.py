"""Continuation-driven input pipeline.

The paper's Listing-2 pattern applied to data loading: each prefetch fill is
an asynchronous host task; its *continuation* re-posts the next fill (like
re-posting a receive), keeping ``depth`` batches in flight without a
dedicated coordinator loop. The trainer never blocks on I/O unless the
buffer is empty, and progress happens on whatever thread touches the engine.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

from repro.core import Engine, HostTaskOp, Status
from repro.models.common import AUDIO, VLM, ModelConfig


class SyntheticTokenSource:
    """Deterministic synthetic batches shaped per architecture family.

    ``fill_latency_s`` simulates storage latency so prefetch overlap is
    observable in tests/benchmarks.
    """

    def __init__(self, cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0, fill_latency_s: float = 0.0) -> None:
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.fill_latency_s = fill_latency_s
        self._seed = seed

    def _token_stream(self, rng, B: int, S: int) -> np.ndarray:
        """Learnable synthetic language: a deterministic affine bigram map
        with 10% noise — CE can fall from ln(V) toward ≈ 0.1·ln(V), so the
        e2e trainer demonstrably learns (uniform-random tokens cannot)."""
        V = self.cfg.vocab_size
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        noise = rng.random((B, S)) < 0.1
        randoms = rng.integers(0, V, (B, S))
        for t in range(1, S):
            nxt = (toks[:, t - 1] * 31 + 7) % V
            toks[:, t] = np.where(noise[:, t], randoms[:, t], nxt)
        return toks.astype(np.int32)

    def make_batch(self, index: int) -> Dict[str, np.ndarray]:
        if self.fill_latency_s:
            time.sleep(self.fill_latency_s)
        rng = np.random.default_rng(self._seed * 100003 + index)
        cfg, B, S = self.cfg, self.global_batch, self.seq_len
        if cfg.family == AUDIO:
            dec = min(cfg.max_target_len, 448)
            return {
                "audio_embed": rng.standard_normal(
                    (B, S, cfg.frontend_dim)).astype(np.float32),
                "dec_tokens": self._token_stream(rng, B, dec),
            }
        batch = {"tokens": self._token_stream(rng, B, S)}
        if cfg.family == VLM:
            batch["tokens"] = batch["tokens"][:, :S - cfg.n_patches]
            batch["patches"] = rng.standard_normal(
                (B, cfg.n_patches, cfg.frontend_dim)).astype(np.float32)
        return batch


class PrefetchPipeline:
    """Double-buffered (depth-N) prefetch built on continuations."""

    def __init__(self, source: SyntheticTokenSource, engine: Engine, *,
                 depth: int = 2, max_batches: Optional[int] = None) -> None:
        self.source = source
        self.engine = engine
        self.depth = depth
        self.max_batches = max_batches
        self._pool = ThreadPoolExecutor(max_workers=depth,
                                        thread_name_prefix="data-fill")
        # thread="any": the executor thread that finished a fill may run the
        # continuation immediately — lowest-latency handoff (paper §3.5).
        self.cr = engine.continue_init({"mpi_continue_thread": "any"})
        # index-ordered delivery: fills complete out of order under
        # concurrency, but training must consume batch i at step i for
        # reproducible restarts
        self._ready: Dict[int, Any] = {}
        self._next_deliver = 0
        self._cv = threading.Condition()
        self._post_lock = threading.Lock()
        self._posted = 0
        self.stats = {"fills": 0, "get_waits": 0}
        for _ in range(depth):
            self._post_fill()

    def _post_fill(self) -> None:
        # continuations may re-post concurrently from executor threads
        with self._post_lock:
            if self.max_batches is not None and self._posted >= self.max_batches:
                return
            index = self._posted
            self._posted += 1
        fut = self._pool.submit(self.source.make_batch, index)
        op = HostTaskOp(fut)
        flag = self.engine.continue_when(op, self._on_fill, index,
                                         status=[None], cr=self.cr)
        if flag:   # already complete: handle immediately (paper §2.2)
            self._on_fill([op.status], index)

    def _on_fill(self, statuses, index) -> None:
        status: Status = statuses[0]
        if status.error is not None:
            raise status.error
        with self._cv:
            self._ready[index] = status.payload
            self._cv.notify_all()
        self.stats["fills"] += 1
        self._post_fill()          # re-post from the continuation body

    def get_next(self, timeout: float = 30.0) -> Dict[str, np.ndarray]:
        deadline = time.monotonic() + timeout
        while True:
            with self._cv:
                if self._next_deliver in self._ready:
                    batch = self._ready.pop(self._next_deliver)
                    self._next_deliver += 1
                    return batch
            self.stats["get_waits"] += 1
            self.engine.tick()      # progress while waiting
            with self._cv:
                if self._next_deliver not in self._ready:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError("prefetch pipeline starved")
                    self._cv.wait(timeout=min(remaining, 0.005))

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        produced = 0
        while self.max_batches is None or produced < self.max_batches:
            yield self.get_next()
            produced += 1

    def close(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)
