"""Heartbeat failure detector built on continuations.

Every rank periodically sends a heartbeat message; the monitor keeps one
pre-posted receive per rank whose *continuation* records liveness and
re-posts itself (the paper's re-post pattern), plus a sweep chained on the
``Promise`` front-end: ``engine.wrap(TimerOp).then(sweep)`` re-arms itself
each tick. Failures fire the registered callback exactly once per rank —
the elastic controller reacts by shrinking the mesh (``runtime.elastic``).

Both registrations ride a plain CR with per-registration
``ContinueFlags(enqueue_complete=True)`` — an already-delivered heartbeat
or already-expired timer still flows through the continuation path.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Set

from repro.core import (ANY_SOURCE, ContinueFlags, Engine, Status, TimerOp,
                        Transport)

HEARTBEAT_TAG = 9101

_HB_FLAGS = ContinueFlags(enqueue_complete=True)


class HeartbeatSender:
    """Rank-side: call ``beat()`` from the rank's main loop (cheap isend)."""

    def __init__(self, transport: Transport, rank: int, monitor_rank: int,
                 interval_s: float = 0.01) -> None:
        self.transport = transport
        self.rank = rank
        self.monitor_rank = monitor_rank
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        if now - self._last >= self.interval_s:
            self._last = now
            self.transport.isend(self.rank, self.monitor_rank, HEARTBEAT_TAG,
                                 ("hb", self.rank, now))


class HeartbeatMonitor:
    def __init__(self, transport: Transport, engine: Engine, rank: int,
                 watched: List[int], timeout_s: float = 0.2,
                 sweep_interval_s: float = 0.05,
                 on_failure: Optional[Callable[[int], None]] = None) -> None:
        self.transport = transport
        self.engine = engine
        self.rank = rank
        self.timeout_s = timeout_s
        self.sweep_interval_s = sweep_interval_s
        self.on_failure = on_failure or (lambda r: None)
        self.last_seen: Dict[int, float] = {r: time.monotonic()
                                            for r in watched}
        self.failed: Set[int] = set()
        self._lock = threading.Lock()
        self._stopped = False
        self._sweep_error: Optional[BaseException] = None
        self.cr = engine.continue_init()
        self._post_recv()
        self._post_sweep()

    # heartbeat receive → record → re-post (continuation body starts new op)
    def _post_recv(self) -> None:
        op = self.transport.irecv(self.rank, source=ANY_SOURCE,
                                  tag=HEARTBEAT_TAG)
        self.engine.continue_when(op, self._on_beat, status=[None],
                                  cr=self.cr, flags=_HB_FLAGS)

    def _on_beat(self, statuses, _):
        status: Status = statuses[0]
        if status.test_cancelled() or self._stopped:
            return
        _, rank, _ = status.payload
        with self._lock:
            self.last_seen[rank] = time.monotonic()
        self._post_recv()

    # periodic sweep via the awaitable front-end: a promise over a TimerOp,
    # whose then-handler re-arms the chain (registered on this monitor's CR
    # so ``progress()`` — one ``cr.test()`` — drives the poll-mode timer).
    # A raising sweep handler (e.g. a broken user on_failure callback) is
    # caught and re-raised from the next progress() call — same surfacing
    # the raw-callback CR error policy gave before the promise migration.
    def _post_sweep(self) -> None:
        (self.engine.wrap(TimerOp(self.sweep_interval_s), cr=self.cr)
         .then(self._on_sweep).catch(self._record_sweep_error))

    def _record_sweep_error(self, exc: BaseException) -> None:
        self._sweep_error = exc

    def _on_sweep(self, _value=None):
        if self._stopped:
            return
        now = time.monotonic()
        newly_failed = []
        with self._lock:
            for rank, seen in self.last_seen.items():
                if rank not in self.failed and now - seen > self.timeout_s:
                    self.failed.add(rank)
                    newly_failed.append(rank)
        for rank in newly_failed:
            self.on_failure(rank)
        self._post_sweep()

    def progress(self) -> None:
        self.cr.test()
        if self._sweep_error is not None:
            err, self._sweep_error = self._sweep_error, None
            raise err

    def stop(self) -> None:
        self._stopped = True
