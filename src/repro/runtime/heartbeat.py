"""Heartbeat failure detector built on continuations.

Every rank periodically sends a heartbeat message; the monitor keeps one
pre-posted receive per rank whose *continuation* records liveness and
re-posts itself (the paper's re-post pattern), plus a sweep chained on the
``Promise`` front-end: ``engine.wrap(TimerOp).then(sweep)`` re-arms itself
each tick. Failures fire the registered callback exactly once per rank —
the elastic controller reacts by shrinking the mesh (``runtime.elastic``).

Both registrations ride a plain CR with per-registration
``ContinueFlags(enqueue_complete=True)`` — an already-delivered heartbeat
or already-expired timer still flows through the continuation path.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Set

from repro.core import (ANY_SOURCE, ContinueFlags, Engine, Status, TimerOp,
                        Transport)

HEARTBEAT_TAG = 9101

_HB_FLAGS = ContinueFlags(enqueue_complete=True)


class HeartbeatSender:
    """Rank-side: call ``beat()`` from the rank's main loop (cheap isend)."""

    def __init__(self, transport: Transport, rank: int, monitor_rank: int,
                 interval_s: float = 0.01) -> None:
        self.transport = transport
        self.rank = rank
        self.monitor_rank = monitor_rank
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        if now - self._last >= self.interval_s:
            self._last = now
            self.transport.isend(self.rank, self.monitor_rank, HEARTBEAT_TAG,
                                 ("hb", self.rank, now))


class HeartbeatMonitor:
    def __init__(self, transport: Transport, engine: Engine, rank: int,
                 watched: List[int], timeout_s: float = 0.2,
                 sweep_interval_s: float = 0.05,
                 on_failure: Optional[Callable[[int], None]] = None,
                 stall_guard_s: Optional[float] = None) -> None:
        self.transport = transport
        self.engine = engine
        self.rank = rank
        self.timeout_s = timeout_s
        self.sweep_interval_s = sweep_interval_s
        self.on_failure = on_failure or (lambda r: None)
        # Self-suspicion guard: when the monitor shares its driver thread
        # with heavy compute (the router's loop jit-compiles replica
        # steps), a long gap between sweeps means beats COULD NOT be
        # observed — silence proves nothing. With ``stall_guard_s`` set,
        # a sweep arriving more than that long after the previous one
        # restarts every silence clock instead of flagging; genuine
        # deaths are still caught one quiet timeout window later.
        self.stall_guard_s = stall_guard_s
        self._last_sweep = time.monotonic()
        # ``last_seen`` is seeded lazily by the first *actual* beat — a
        # construction-time timestamp would vouch for ranks the monitor
        # has never heard from. Until a rank beats, the sweep measures
        # silence against its ``watch()`` time instead, so a rank that is
        # dead on arrival is still flagged one timeout after watch-start.
        self.last_seen: Dict[int, float] = {}
        self._watch_start: Dict[int, float] = {}
        self.failed: Set[int] = set()
        self._lock = threading.Lock()
        self._stopped = False
        self._sweep_error: Optional[BaseException] = None
        for r in watched:
            self.watch(r)
        self.cr = engine.continue_init()
        self._post_recv()
        self._post_sweep()

    # ------------------------------------------------------- watch set
    def watch(self, rank: int, now: Optional[float] = None) -> None:
        """(Re-)watch ``rank``: its silence clock starts now. Re-watching
        a failed rank clears its failure so recovery can be observed."""
        now = time.monotonic() if now is None else now
        with self._lock:
            self._watch_start[rank] = now
            self.last_seen.pop(rank, None)
            self.failed.discard(rank)

    def unwatch(self, rank: int) -> None:
        """Stop watching ``rank`` (elastic shrink: a rank the controller
        already removed must not re-fire ``on_failure``)."""
        with self._lock:
            self._watch_start.pop(rank, None)
            self.last_seen.pop(rank, None)
            self.failed.discard(rank)

    @property
    def watched(self) -> List[int]:
        with self._lock:
            return sorted(self._watch_start)

    # heartbeat receive → record → re-post (continuation body starts new op)
    def _post_recv(self) -> None:
        op = self.transport.irecv(self.rank, source=ANY_SOURCE,
                                  tag=HEARTBEAT_TAG)
        self.engine.continue_when(op, self._on_beat, status=[None],
                                  cr=self.cr, flags=_HB_FLAGS)

    def _on_beat(self, statuses, _):
        status: Status = statuses[0]
        if status.test_cancelled() or self._stopped:
            return
        _, rank, _ = status.payload
        with self._lock:
            if rank in self._watch_start:
                self.last_seen[rank] = time.monotonic()
        self._post_recv()

    # periodic sweep via the awaitable front-end: a promise over a TimerOp,
    # whose then-handler re-arms the chain (registered on this monitor's CR
    # so ``progress()`` — one ``cr.test()`` — drives the poll-mode timer).
    # A raising sweep handler (e.g. a broken user on_failure callback) is
    # caught and re-raised from the next progress() call — same surfacing
    # the raw-callback CR error policy gave before the promise migration.
    def _post_sweep(self) -> None:
        (self.engine.wrap(TimerOp(self.sweep_interval_s), cr=self.cr)
         .then(self._on_sweep).catch(self._record_sweep_error))

    def _record_sweep_error(self, exc: BaseException) -> None:
        self._sweep_error = exc

    def _on_sweep(self, _value=None):
        if self._stopped:
            return
        now = time.monotonic()
        gap, self._last_sweep = now - self._last_sweep, now
        if self.stall_guard_s is not None and gap > self.stall_guard_s:
            with self._lock:
                for rank in self._watch_start:
                    self._watch_start[rank] = now
                    self.last_seen.pop(rank, None)
            self._post_sweep()
            return
        newly_failed = []
        with self._lock:
            for rank, started in self._watch_start.items():
                seen = self.last_seen.get(rank, started)
                if rank not in self.failed and now - seen > self.timeout_s:
                    self.failed.add(rank)
                    newly_failed.append(rank)
        for rank in newly_failed:
            self.on_failure(rank)
        self._post_sweep()

    def progress(self) -> None:
        self.cr.test()
        if self._sweep_error is not None:
            err, self._sweep_error = self._sweep_error, None
            raise err

    def stop(self) -> None:
        self._stopped = True
