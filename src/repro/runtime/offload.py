"""Diffusive task offloading for straggler mitigation (paper §5.4).

The ExaHyPE scheme, rebuilt on this framework's transport: overloaded
(critical) ranks offload tasks to underloaded ranks. One offload is a
*group* of messages — task metadata + task input on the way out, and three
messages (result meta, result data, timing) on the way back — whose combined
completion triggers a single callback, exactly the request-group pattern the
paper replaces with ``MPIX_Continueall``.

Two interchangeable completion backends drive the comparison benchmarks
(and the Table-3 LoC analogue):

* ``ContinuationBackend`` — ``continue_all`` + ``enqueue_complete`` CR;
  completions fire as soon as any thread touches the engine/transport.
* ``TestsomeBackend`` — the reference application-space manager with a
  bounded ``MPI_Testsome`` window (completion of recently-posted requests is
  invisible until promoted into the window — the latency artifact the paper
  measures).

Emergencies (paper): a result that misses the iteration deadline halves the
quota toward that target and suspends it for a few timesteps; on-time
results grow quotas diffusively.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import (ANY_SOURCE, Engine, Status, TestsomeManager,
                        Transport)

TASK_META = 7001
TASK_DATA = 7002
RESULT_META = 7003
RESULT_DATA = 7004
RESULT_TIMING = 7005
LOAD_REPORT = 7006


# --------------------------------------------------------------- backends
class ContinuationBackend:
    """Group completion via MPIX_Continueall semantics (the paper's path)."""

    def __init__(self, engine: Engine) -> None:
        self.engine = engine
        self.cr = engine.continue_init(
            {"mpi_continue_enqueue_complete": True})

    def submit(self, ops: Sequence, cb: Callable, cb_data: Any = None) -> None:
        statuses = [None] * len(ops)
        self.engine.continue_all(ops, cb, cb_data, statuses=statuses,
                                 cr=self.cr)

    def progress(self) -> None:
        self.cr.test()

    def outstanding(self) -> int:
        return self.cr.active_count


class TestsomeBackend:
    """Reference: request groups via parallel arrays + Testsome window."""

    __test__ = False     # keep pytest from collecting this backend class

    def __init__(self, window: int = 16) -> None:
        self.manager = TestsomeManager(window=window)

    def submit(self, ops: Sequence, cb: Callable, cb_data: Any = None) -> None:
        self.manager.submit(list(ops), cb, cb_data, want_statuses=True)

    def progress(self) -> None:
        self.manager.testsome()

    def outstanding(self) -> int:
        return self.manager.outstanding


# ------------------------------------------------------------------ tasks
class Task:
    __slots__ = ("task_id", "cost_s", "payload", "done", "t_offloaded",
                 "result")

    def __init__(self, task_id: int, cost_s: float,
                 payload: Optional[np.ndarray] = None) -> None:
        self.task_id = task_id
        self.cost_s = cost_s
        self.payload = payload if payload is not None else \
            np.full((64,), float(task_id), np.float32)
        self.done = threading.Event()
        self.t_offloaded = 0.0
        self.result: Any = None


def default_compute(cost_s: float, payload: np.ndarray) -> np.ndarray:
    """Burn ~cost_s of CPU (busy-ish wait keeps the GIL mostly released)."""
    time.sleep(cost_s)
    return payload * 2.0 + 1.0


class OffloadManager:
    """Per-rank offloading endpoint + diffusive quota controller."""

    def __init__(self, rank: int, n_ranks: int, transport: Transport,
                 backend, *, compute: Callable = default_compute,
                 prepost: int = 4, quota_max: int = 64) -> None:
        self.rank = rank
        self.n_ranks = n_ranks
        self.transport = transport
        self.backend = backend
        self.compute = compute
        self.quota_max = quota_max
        self.quota: Dict[int, int] = {r: 1 for r in range(n_ranks)
                                      if r != rank}
        self.suspended: Dict[int, int] = {}
        self._task_seq = rank * 1_000_000
        self.inflight: Dict[int, Task] = {}
        self._lock = threading.Lock()
        self.stats = {"offloaded": 0, "executed_remote": 0, "emergencies": 0,
                      "returned": 0}
        self._stopped = False
        for _ in range(prepost):
            self._post_meta_recv()

    # ------------------------------------------------------- victim side
    def _post_meta_recv(self) -> None:
        op = self.transport.irecv(self.rank, source=ANY_SOURCE, tag=TASK_META)
        self.backend.submit([op], self._on_task_meta)

    def _on_task_meta(self, statuses, _):
        status: Status = statuses[0]
        if status.test_cancelled() or self._stopped:
            return
        task_id, source, cost_s = status.payload
        data_op = self.transport.irecv(self.rank, source=source,
                                       tag=TASK_DATA)
        self.backend.submit([data_op], self._on_task_data,
                            (task_id, source, cost_s))
        self._post_meta_recv()     # re-arm (paper: pre-posted receives)

    def _on_task_data(self, statuses, meta):
        task_id, source, cost_s = meta
        payload = statuses[0].payload
        result = self.compute(cost_s, payload)
        self.stats["executed_remote"] += 1
        # result travels as three messages (paper §5.4 / Fig. 7)
        self.transport.isend(self.rank, source, RESULT_META,
                             (task_id, self.rank))
        self.transport.isend(self.rank, source, RESULT_DATA,
                             (task_id, result))
        self.transport.isend(self.rank, source, RESULT_TIMING,
                             (task_id, time.monotonic()))

    # ------------------------------------------------------- source side
    def offload(self, task: Task, target: int) -> None:
        task.t_offloaded = time.monotonic()
        with self._lock:
            self.inflight[task.task_id] = task
        s_meta = self.transport.isend(self.rank, target, TASK_META,
                                      (task.task_id, self.rank, task.cost_s))
        s_data = self.transport.isend(self.rank, target, TASK_DATA,
                                      task.payload)
        # post the three result receives in the continuation of the sends —
        # keeps the active request count low (paper §5.4)
        self.backend.submit(
            [s_meta, s_data], self._on_sends_complete, (task.task_id, target))
        self.stats["offloaded"] += 1

    def _on_sends_complete(self, statuses, meta):
        task_id, target = meta
        recvs = [
            self.transport.irecv(self.rank, source=target, tag=RESULT_META),
            self.transport.irecv(self.rank, source=target, tag=RESULT_DATA),
            self.transport.irecv(self.rank, source=target, tag=RESULT_TIMING),
        ]
        self.backend.submit(recvs, self._on_result, task_id)

    def _on_result(self, statuses, task_id):
        _, result = statuses[1].payload
        with self._lock:
            task = self.inflight.pop(task_id, None)
        if task is not None:
            task.result = result
            task.done.set()
            self.stats["returned"] += 1

    # ------------------------------------------------- diffusive control
    def pick_target(self, loads: Dict[int, float]) -> Optional[int]:
        """Least-loaded, non-suspended rank with remaining quota."""
        candidates = [(loads.get(r, 0.0), r) for r in self.quota
                      if self.suspended.get(r, 0) <= 0 and self.quota[r] > 0]
        if not candidates:
            return None
        return min(candidates)[1]

    def end_iteration(self, deadline_missed: Dict[int, bool]) -> None:
        """Diffusive quota update (paper's emergency mechanism)."""
        just_suspended = set()
        for target, missed in deadline_missed.items():
            if missed:
                self.stats["emergencies"] += 1
                self.quota[target] = max(1, self.quota[target] // 2)
                self.suspended[target] = 3
                just_suspended.add(target)
            else:
                # multiplicative-increase ramp (halved on emergencies above)
                self.quota[target] = min(self.quota_max,
                                         max(self.quota[target] + 1,
                                             self.quota[target] * 2))
        for r in list(self.suspended):
            if r not in just_suspended:
                self.suspended[r] = max(0, self.suspended[r] - 1)

    def new_task(self, cost_s: float) -> Task:
        self._task_seq += 1
        return Task(self._task_seq, cost_s)

    def stop(self) -> None:
        self._stopped = True
