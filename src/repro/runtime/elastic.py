"""Elastic scaling: shrink/regrow the mesh and re-shard state.

At 1000+ nodes the failure model is "a pod (or slice) drops out"; recovery
is: detect (heartbeat) → rebuild the mesh on the surviving device set →
restore the latest committed checkpoint re-sharded onto the new mesh →
resume. ``reshard_state`` also serves planned elastic *expansion* (new pod
joins): the same checkpoint restores onto the larger mesh.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.sharding import specs_to_shardings


def build_mesh(devices: Sequence, shape: Tuple[int, ...],
               axes: Tuple[str, ...]) -> Mesh:
    n = int(np.prod(shape))
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    arr = np.asarray(devices[:n]).reshape(shape)
    return Mesh(arr, axes)


def shrink_after_failure(devices: Sequence, shape: Tuple[int, ...],
                         axes: Tuple[str, ...],
                         failed: Sequence) -> Tuple[Mesh, Tuple[int, ...]]:
    """Drop the outermost-axis slices containing failed devices and rebuild.

    The outermost axis is the scale-out axis ("pod" on the production mesh):
    losing any device in a pod evicts that pod — the TPU failure domain.
    """
    failed_ids = {id(d) for d in failed} | {getattr(d, "id", None)
                                            for d in failed}
    arr = np.asarray(devices[:int(np.prod(shape))]).reshape(shape)
    keep_slices = []
    for i in range(shape[0]):
        block = arr[i].ravel()
        if any(getattr(d, "id", None) in failed_ids or id(d) in failed_ids
               for d in block):
            continue
        keep_slices.append(arr[i])
    if not keep_slices:
        raise RuntimeError("no surviving slices")
    new_shape = (len(keep_slices),) + tuple(shape[1:])
    new_arr = np.stack(keep_slices)
    return Mesh(new_arr, axes), new_shape


def reshard_state(state: Any, spec_tree: Any, new_mesh: Mesh,
                  rules: Optional[Dict] = None,
                  overrides: Optional[Dict] = None) -> Any:
    """Re-place every leaf onto the new mesh per its logical spec."""
    shardings = specs_to_shardings(spec_tree, new_mesh, rules, overrides)

    def put(x, s):
        if s is None:
            return jax.device_put(np.asarray(x))
        return jax.device_put(np.asarray(x), s)

    return jax.tree_util.tree_map(
        put, state, shardings)
